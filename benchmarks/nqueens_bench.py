"""Paper Figs 12/13: N-Queens — serial vs serverless prefix-task offload.

The paper runs N=17/18 with prefixes 1–3 on AWS (up to 894x speedup, limited
by task heterogeneity).  This container is one CPU core, so we MEASURE a
scaled-down N and MODEL the paper-scale deployment with the calibrated
latency model: per-task durations measured locally (they are the real
subtree sizes — the heterogeneity is real), makespan = latency-model burst.
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.nqueens import KNOWN, count_completions, prefixes, \
    solve_serial
from repro.dispatch import DEFAULT_LATENCY

import jax


def run(n: int = 11, plist=(1, 2)):
    t0 = time.perf_counter()
    total_serial = solve_serial(n)
    serial_s = time.perf_counter() - t0
    assert total_serial == KNOWN.get(n, total_serial)

    out = {"n": n, "solutions": total_serial, "serial_s": serial_s,
           "prefix": {}}
    count_jit = jax.jit(count_completions, static_argnums=(0,))
    for p in plist:
        tasks = prefixes(n, p)
        # measure real per-task durations (heterogeneous subtree sizes)
        durs_ms, counts = [], []
        count_jit(n, *map(int, tasks[0]))          # warm compile
        for ld, rd, col in tasks:
            t1 = time.perf_counter()
            c = int(count_jit(n, int(ld), int(rd), int(col)))
            durs_ms.append((time.perf_counter() - t1) * 1e3)
            counts.append(c)
        assert sum(counts) == total_serial, (p, sum(counts))

        lats = DEFAULT_LATENCY.simulate_burst(durs_ms)
        makespan_s = max(lats) / 1e3
        out["prefix"][p] = {
            "tasks": len(tasks),
            "sum_task_s": sum(durs_ms) / 1e3,
            "max_task_ms": max(durs_ms),
            "median_task_ms": float(np.median(durs_ms)),
            "heterogeneity_max_over_median":
                max(durs_ms) / max(1e-9, float(np.median(durs_ms))),
            "modeled_serverless_makespan_s": makespan_s,
            "modeled_speedup_vs_serial": serial_s / makespan_s,
            "ideal_speedup_tasks": len(tasks),
        }
    out["paper_claims"] = {
        "n17_p2_speedup": 164.0, "n18_p3_speedup": 894.0,
        "observation": "speedup < #tasks because the longest task bounds "
                       "the makespan (heterogeneity), matching the "
                       "max/median ratio above",
    }
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
