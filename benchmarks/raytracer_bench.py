"""Paper Fig 1 + Fig 14: tiled Monte-Carlo raytracer.

Fig 1: serial vs serverless tiles (paper: 500x500, 33.9x at tile 16x16).
Fig 14: total cost in GB-seconds vs parallelism — the pay-as-you-go claim
(cost ~flat as tiles shrink and worker count grows).

Execution is real (every tile is rendered through the dispatcher on the
worker pool); the makespan a cloud client would see comes from the latency
model over the real per-tile durations, since this container has one core.
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.raytracer import random_scene, render_serial, \
    render_serverless
from repro.cloud import Session
from repro.dispatch import DEFAULT_LATENCY


def run(width: int = 96, spp: int = 3, tiles=(48, 24, 12)):
    scene = random_scene(width=width, height=width, n_spheres=24)

    t0 = time.perf_counter()
    img_serial = render_serial(scene, spp=spp)
    serial_s = time.perf_counter() - t0

    out = {"image": f"{width}x{width}", "spp": spp, "serial_s": serial_s,
           "tiles": {}}
    for tile in tiles:
        # os_threads=1: workers on this container share ONE core, so
        # concurrent execution would bill contention (wall ≈ K x cpu) and
        # fake a cost increase with parallelism; sequential execution gives
        # each task its true single-worker duration (cloud workers are
        # independent machines), and the latency model supplies the
        # parallel makespan.
        sess = Session("threads", os_threads=1)
        img, _ = render_serverless(scene, tile=tile, spp=spp, session=sess)
        assert np.isfinite(img).all()
        durs_ms = [r.server_s * 1e3 for r in sess.records]
        lats = DEFAULT_LATENCY.simulate_burst(durs_ms)
        makespan_s = max(lats) / 1e3
        cost = sess.cost
        out["tiles"][tile] = {
            "workers": len(durs_ms),
            "mean_abs_err_vs_serial": float(np.abs(img - img_serial).mean()),
            "sum_task_s": sum(durs_ms) / 1e3,
            "max_task_ms": max(durs_ms),
            "median_task_ms": float(np.median(durs_ms)),
            "modeled_makespan_s": makespan_s,
            "modeled_speedup": serial_s / makespan_s,
            "gb_seconds": cost.gb_seconds,
            "dollars": cost.dollars,
            "payload_bytes_per_invocation": int(np.mean(
                [r.payload_bytes for r in sess.records])),
        }
        sess.close()

    gbs = [v["gb_seconds"] for v in out["tiles"].values()]
    out["claims"] = {
        "paper_speedup_tile16": 33.9,
        "paper_cost_flat": "Fig 14: GB-s ~constant vs parallelism",
        "cost_flatness_max_over_min": max(gbs) / min(gbs),
        "paper_payload_kib": 88.0,
    }
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
