"""Paper Tables 9/10: serialization formats on the two paper payloads.

Table 9: an array of 1,000,000 uint64.
Table 10: an array of structs (two ints + a string, custom serializer).

Formats: binary (cereal-binary analogue), binary_json (base64-wrapped binary
inside a JSON envelope — what a JSON-only FaaS API forces), structured_json.
Reports ms + GiB/s per (format × encode/decode) and the paper's headline
ratio (binary_json vs structured_json speedup).
"""
from __future__ import annotations

import time

import numpy as np

from repro.serialization import deserialize, serialize

FORMATS = ("binary", "binary_json", "structured_json")


def _time(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _bench_payload(payload, nbytes: int, reps: int = 3):
    rows = {}
    for fmt in FORMATS:
        enc_s, blob = _time(lambda f=fmt: serialize(payload, format=f), reps)
        dec_s, back = _time(lambda b=blob, f=fmt: deserialize(b, format=f),
                            reps)
        rows[fmt] = {
            "encode_ms": enc_s * 1e3, "decode_ms": dec_s * 1e3,
            "encode_gib_s": nbytes / enc_s / 2**30,
            "decode_gib_s": nbytes / dec_s / 2**30,
            "wire_bytes": len(blob),
        }
    return rows


def bench_uint_array(n: int = 1_000_000):
    """Table 9."""
    arr = np.arange(n, dtype=np.uint64)
    return _bench_payload(arr, arr.nbytes)


def bench_structs(n: int = 120_000):
    """Table 10 — two ints and a string per record.

    The binary formats serialize the framework's *columnar record batch*
    (struct-of-arrays: int columns + a flat string heap with offsets) —
    the array-native analogue of cereal's compiled per-struct serializers;
    a Python-level per-record walk would benchmark the interpreter, not
    the format.  structured_json encodes the records as actual structured
    JSON (the loosely-typed wire format FaaS REST APIs force).
    """
    rng = np.random.default_rng(0)
    recs = [{"a": int(rng.integers(0, 1 << 30)),
             "b": int(rng.integers(0, 1 << 30)),
             "s": "payload-" + str(int(rng.integers(0, 1 << 20)))}
            for _ in range(n)]
    nbytes = sum(16 + len(r["s"]) for r in recs)

    # columnar record batch (construction excluded, like the paper's
    # already-in-memory std::vector<struct>)
    strings = [r["s"].encode() for r in recs]
    batch = {
        "a": np.asarray([r["a"] for r in recs], np.int64),
        "b": np.asarray([r["b"] for r in recs], np.int64),
        "s_heap": np.frombuffer(b"".join(strings), np.uint8),
        "s_off": np.cumsum([0] + [len(s) for s in strings]).astype(np.int32),
    }

    rows = {}
    for fmt in FORMATS:
        payload = recs if fmt == "structured_json" else batch
        enc_s, blob = _time(lambda f=fmt, p=payload: serialize(p, format=f),
                            2)
        dec_s, _ = _time(lambda b=blob, f=fmt: deserialize(b, format=f), 2)
        rows[fmt] = {
            "encode_ms": enc_s * 1e3, "decode_ms": dec_s * 1e3,
            "encode_gib_s": nbytes / enc_s / 2**30,
            "decode_gib_s": nbytes / dec_s / 2**30,
            "wire_bytes": len(blob),
        }
    return rows


PAPER_TABLE9 = {  # ms, from the paper
    "binary": {"encode_ms": 5.90, "decode_ms": 3.18},
    "binary_json": {"encode_ms": 13.03, "decode_ms": 28.63},
    "structured_json": {"encode_ms": 462.40, "decode_ms": 144.15},
}


def run():
    t9 = bench_uint_array()
    t10 = bench_structs()

    def ratio(rows, a, b, key):
        return rows[b][key] / rows[a][key]

    summary = {
        "table9_uint64_array": t9,
        "table10_structs": t10,
        "claims": {
            # paper: binary beats structured_json by ~2 orders of magnitude
            "t9_binary_vs_structured_encode_x":
                ratio(t9, "binary", "structured_json", "encode_ms"),
            "t9_paper_binary_vs_structured_encode_x":
                PAPER_TABLE9["structured_json"]["encode_ms"]
                / PAPER_TABLE9["binary"]["encode_ms"],
            # paper §5.1: binary_json up to 5.52x faster than vanilla JSON
            "t10_binary_json_vs_structured_x":
                ratio(t10, "binary_json", "structured_json", "encode_ms"),
            "paper_t10_binary_json_vs_structured_x": 5.52,
        },
    }
    return summary


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
