"""Serving load generator: waves vs batch-level vs iteration-level.

  PYTHONPATH=src python -m benchmarks.serve_bench \
      [--backend threads|processes|http|...] [--requests 48] \
      [--concurrency 32] [--open-rate 0] [--prefix-shared 0.5] \
      [--json BENCH_serving.json]

Closed loop (default): ``--concurrency`` clients each keep one request
outstanding until ``--requests`` total have completed — the paper's
fork-join client turned into sustained traffic.  Open loop
(``--open-rate`` req/s): Poisson arrivals, latency includes queueing the
way a real client sees it.

Three schedulers over the *same* model entry points:

* ``waves``            — ``LMServer.serve``: fixed fork-join partition
                         into ``--wave``-sized batches.
* ``continuous-batch`` — ``ContinuousBatcher`` pinned to the PR 4
                         batch-level path (``iteration_level=False``):
                         slot admission *between* batches, every batch
                         re-runs prefill.
* ``continuous``       — the ISSUE 5 iteration-level path where the
                         backend supports worker-resident state: KV cache
                         arenas live on the workers, admission every
                         ``--quantum`` decode steps, eviction at
                         ``max_new`` without batch-tail wait, and a
                         worker-resident prompt-prefix cache that lets
                         repeated prompts skip prefill entirely.
* ``continuous-paged`` — the ISSUE 7 paged twin of ``continuous``
                         (``--paged on`` adds it): each arena is a
                         refcounted block pool with per-row block tables,
                         prompts sharing a prefix share physical blocks
                         through a worker-resident radix index (partial
                         hits skip prefill for the matched head), and
                         long prompts chunk-prefill instead of falling
                         back to solo waves.  The scheduler summary
                         reports pool occupancy peaks (live tokens,
                         allocated blocks, radix-shared blocks) and the
                         JSON gains paged-vs-slot A/B numbers.

Requests are *long-tail mixed* on both axes (decode ~3/4 short at
``max_new/8``; prompts ~3/4 short at ``prompt_len/4``), and
``--prefix-shared`` replaces that fraction of prompts with one shared
system prompt of length ``--prompt-len`` — the workload where prefix
reuse shows up.  Reported per mode: throughput, completion-latency
percentiles, **TTFT** percentiles (time to first token — batch-level
schedulers have no token stream, so their TTFT *is* the completion
latency) and **TPOT** (time per output token after the first).

``--fleet N`` adds fleet mode (ISSUE 6): N engine-loop members on N
affinity-pinned workers behind the prefix-aware ``FleetRouter``, plus two
A/B baselines — ``fleet-random`` (same fleet, uniform-random placement)
and ``single`` (ONE worker carrying the same total arena slots).  The
JSON gains ``fleet_speedup_vs_single`` and
``ttft_p50_prefix_vs_random_ms``, per-member served/migration counts,
routing and scale-event logs, and per-worker busy-time shares from
``Session.stats()``.  ``--fleet-disaggregate on`` splits prefill/decode
roles; ``--fleet-elastic on`` (default) starts at ``--fleet-min`` and
scales on backlog/occupancy.

``--chaos kill-member`` (ISSUE 10) runs the chaos drill instead of the
regular modes: a seeded :class:`~repro.runtime.sandbox.ChaosPlan` is
armed after warmup and the transport client SIGKILLs one fleet member's
worker mid-decode.  The run asserts nothing itself — it *records*
everything (chaos events, per-row recovery, retry timestamps, recovered
vs untouched latency percentiles) into the ``repro.serve_chaos/v1``
document that CI's chaos smoke step asserts on.  Other kinds:
``drop-conn``, ``stall``, ``expire-lease``.

``--json`` writes the machine-readable ``repro.serve_bench/v2`` schema
(see ``make_result``); CI's serving smoke steps run tiny instances on
every push.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import random
import sys
import time

import numpy as np


# ------------------------------------------------------------- workload ----

def make_requests(cfg, n: int, prompt_len: int, max_new: int, seed: int = 0,
                  prefix_shared: float = 0.0, prefix_suffixes: int = 0):
    """Long-tail request mix on BOTH axes: ~3/4 short, ~1/4 long, for the
    prompt length and (independently) the decode length; ``prefix_shared``
    of the requests instead carry one identical shared prompt (the
    system-prompt pattern the prefix cache exists for).  With
    ``prefix_suffixes > 0`` the shared requests carry the shared *system
    prefix* (3/4 of ``prompt_len``) plus one of that many user suffixes —
    the fleet-routing workload, where the router's prefix key is the
    system prefix (``shared_prefix_len``) rather than the whole prompt."""
    from repro.runtime.server import Request
    rng = np.random.default_rng(seed)
    short_new = max(1, max_new // 8)
    short_prompt = max(1, prompt_len // 4)
    shared = list(rng.integers(1, cfg.vocab_size, prompt_len))
    head = shared[:shared_prefix_len(prompt_len)]
    tails = [list(rng.integers(1, cfg.vocab_size,
                               max(1, prompt_len - len(head))))
             for _ in range(max(0, prefix_suffixes))]
    out = []
    for _ in range(n):
        if prefix_shared > 0 and rng.random() < prefix_shared:
            prompt = (head + tails[int(rng.integers(len(tails)))]
                      if tails else list(shared))
        else:
            prompt = list(rng.integers(
                1, cfg.vocab_size,
                (short_prompt if rng.random() < 0.75 else prompt_len)))
        out.append(Request(
            prompt=prompt,
            max_new=(short_new if rng.random() < 0.75 else max_new)))
    return out


def shared_prefix_len(prompt_len: int) -> int:
    """Length of the shared system prefix in the suffix-pool workload —
    the router's content-hash key covers exactly this many tokens."""
    return max(1, (3 * prompt_len) // 4)


def make_server(backend: str, arch: str, max_new: int, os_threads: int,
                chaos=None):
    import jax
    from repro.cloud import Session
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.runtime.server import LMServer

    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(backend, os_threads=os_threads, chaos=chaos)
    server = LMServer(cfg, params, session=session, max_new=max_new)
    return cfg, session, server


def warmup(server, cfg, max_new: int, prompt_len: int, batch: int) -> None:
    """Pay every decode bucket's AOT compile at the *real* packed shapes
    (batch/prompt shape buckets, short AND long prompt buckets — the
    long-tail mix produces both) before timing anything."""
    from repro.runtime.server import Request, decode_bucket, shape_bucket
    for plen in sorted({shape_bucket(max(1, prompt_len // 4)),
                        shape_bucket(prompt_len)}):
        prompt = list(range(1, plen + 1))
        for b in sorted({decode_bucket(max(1, max_new // 8)),
                         decode_bucket(max_new)}):
            server.serve_wave([Request(prompt=prompt, max_new=b)] * batch)


def warmup_iteration(server, cfg, max_new: int, prompt_len: int, wave: int,
                     slots: int, **batcher_kwargs) -> None:
    """Untimed pass through the iteration-level scheduler itself: pays the
    engine entry points' jit compiles (prefill per prompt-width bucket,
    decode per chunk-length bucket) on the same affinity-pinned workers
    the timed run will use — the engine analogue of ``warmup``."""
    from repro.runtime.server import Request, shape_bucket
    from repro.serving import run_continuous
    plens = sorted({shape_bucket(max(1, prompt_len // 4)),
                    shape_bucket(prompt_len)})
    prompt_of = {plen: list(range(1, plen + 1)) for plen in plens}
    if batcher_kwargs.get("paged"):
        # chunked prefill splits a prompt wherever the per-call budget
        # lands, so ANY pow2 chunk-width bucket up to the longest prompt
        # can occur mid-run — compile them all here, or a budget split
        # would pay a fresh jit inside the measured window.  Widest
        # first (the first admission of a call always gets its full
        # width) and with a distinct token head per width, so neither a
        # budget split nor a radix prefix hit shrinks the first chunk of
        # a group below its bucket
        plens, w = [], 1
        while w <= shape_bucket(prompt_len):
            plens.append(w)
            w *= 2
        plens.reverse()
        prompt_of = {plen: list(range(plen, 2 * plen)) for plen in plens}
    reqs = []
    for plen in plens:
        for new in sorted({max(1, max_new // 8), max_new}):
            reqs.extend([Request(prompt=list(prompt_of[plen]),
                                 max_new=new)] * wave)
    run_continuous(server, reqs, concurrency=wave * slots, max_batch=wave,
                   slots=slots, iteration_level=True, **batcher_kwargs)


def warmup_fleet(server, cfg, max_new: int, prompt_len: int, wave: int,
                 n_members: int, **fleet_kwargs) -> None:
    """Untimed non-elastic fleet pass: spawns all ``n_members`` so every
    member's worker pays its engine jit compiles (prefill and decode per
    shape bucket, per role) before the timed — possibly elastic — run
    lands on the same affinity indices warm."""
    from repro.fleet import run_fleet
    from repro.runtime.server import Request, shape_bucket
    reqs = []
    for plen in sorted({shape_bucket(max(1, prompt_len // 4)),
                        shape_bucket(prompt_len)}):
        for new in sorted({max(1, max_new // 8), max_new}):
            reqs.extend([Request(prompt=list(range(1, plen + 1)),
                                 max_new=new)] * wave)
    fleet_kwargs.setdefault("max_batch", wave)
    run_fleet(server, reqs, concurrency=wave * n_members,
              n_members=n_members, elastic=False, **fleet_kwargs)


def worker_utilization(session) -> dict:
    """Per-worker cold/warm and busy-time evidence (satellite: sandbox
    counters surfaced through ``Session.stats()``).  Busy seconds include
    warmup — shares across workers are the meaningful number."""
    try:
        st = session.stats()
    except Exception as e:       # pragma: no cover - backend without stats
        return {"error": repr(e)}
    busy = {str(i): round(w.get("sandboxes", {}).get("busy_s", 0.0), 3)
            for i, w in st.get("workers", {}).items() if isinstance(w, dict)}
    total = sum(busy.values())
    return {"n_workers": st.get("n_workers"),
            "cold_starts": st.get("cold_starts"),
            "warm_hits": st.get("warm_hits"),
            "busy_s": round(st.get("busy_s", 0.0), 3),
            "per_worker_busy_s": busy,
            "per_worker_busy_share": {
                i: round(b / total, 3) for i, b in busy.items()} if total
            else {}}


def percentiles(lats_ms: list[float], prefix: str = "") -> dict:
    a = np.asarray(lats_ms, dtype=np.float64)
    return {f"{prefix}p50_ms": float(np.percentile(a, 50)),
            f"{prefix}p95_ms": float(np.percentile(a, 95)),
            f"{prefix}p99_ms": float(np.percentile(a, 99)),
            f"{prefix}mean_ms": float(a.mean())}


def summarize(lats_ms: list[float], wall_s: float, n_requests: int,
              tokens: int, ttfts_ms: list[float] | None = None,
              tpots_ms: list[float] | None = None) -> dict:
    out = {"requests": n_requests, "wall_s": round(wall_s, 3),
           "throughput_rps": round(n_requests / wall_s, 3),
           "tokens_per_s": round(tokens / wall_s, 3)}
    out.update({k: round(v, 2) for k, v in percentiles(lats_ms).items()})
    if ttfts_ms:
        out.update({k: round(v, 2)
                    for k, v in percentiles(ttfts_ms, "ttft_").items()})
    if tpots_ms:
        out.update({k: round(v, 3)
                    for k, v in percentiles(tpots_ms, "tpot_").items()})
    return out


def _token_metrics(comps, lats_ms):
    """Client-side TTFT/TPOT from the scheduler's per-token stamps.

    Iteration-level completions carry ``token_times_ms`` — stamped ONCE at
    each decode-chunk reply by the batcher — so TTFT is ``times[0]``
    (equal to ``ttft_ms`` by construction; asserted) and TPOT is the
    measured inter-token spread ``(times[-1] - times[0]) / (n - 1)``
    instead of being re-derived from the completion latency.  The derived
    TPOT can only over-estimate (latency includes the post-decode join),
    which the assert pins down.  Batch-level completions have no token
    stream: TTFT falls back to the completion latency and TPOT to the old
    derivation — the honest numbers for a scheduler whose whole batch
    joins at once."""
    ttfts, tpots = [], []
    for comp, lat in zip(comps, lats_ms):
        times = comp.token_times_ms
        if times:
            assert comp.ttft_ms is None or times[0] == comp.ttft_ms, \
                (times[0], comp.ttft_ms)
            ttfts.append(times[0])
            if len(times) > 1:
                tpot = (times[-1] - times[0]) / (len(times) - 1)
                derived = max(0.0, lat - times[0]) / (len(times) - 1)
                assert tpot <= derived + 1e-6, (tpot, derived)
                tpots.append(tpot)
            continue
        ttft = comp.ttft_ms if comp.ttft_ms is not None else lat
        ttfts.append(ttft)
        n = len(comp.tokens)
        if n > 1:
            tpots.append(max(0.0, lat - ttft) / (n - 1))
    return ttfts, tpots


# ----------------------------------------------------------- sync waves ----

def bench_waves(server, requests, *, wave_size: int, slots: int) -> dict:
    """Fixed fork-join: all requests present at t0, ``wave_size`` batches,
    ``slots`` waves in flight; a request's client-observed latency is its
    wave's completion time (the whole wave joins before anyone unpacks)."""
    waves = [requests[i:i + wave_size]
             for i in range(0, len(requests), wave_size)]
    t0 = time.perf_counter()
    futs, done_at = [], [0.0] * len(waves)

    def settle(i):
        futs[i].result()
        done_at[i] = time.perf_counter() - t0

    for i, w in enumerate(waves):
        if i >= slots:
            settle(i - slots)              # free the oldest payload
        futs.append(server.submit_wave(w, min_rows=wave_size))
    for i in range(max(0, len(waves) - slots), len(waves)):
        settle(i)
    comps = []
    for w, f in zip(waves, futs):
        comps.extend(server.unpack_wave(w, f))
    wall = time.perf_counter() - t0
    lats = [done_at[i // wave_size] * 1000.0 for i in range(len(requests))]
    tokens = sum(len(c.tokens) for c in comps)
    ttfts, tpots = _token_metrics(comps, lats)
    return summarize(lats, wall, len(requests), tokens, ttfts, tpots)


# ----------------------------------------------------- async continuous ----

def bench_continuous(server, requests, *, concurrency: int, max_batch: int,
                     slots: int, max_wait_ms: float,
                     open_rate: float = 0.0, seed: int = 0,
                     **batcher_kwargs) -> dict:
    """Closed loop (``open_rate==0``): ``concurrency`` clients back to
    back.  Open loop: Poisson arrivals at ``open_rate`` req/s, latency
    measured from *arrival* (queueing included).  ``batcher_kwargs``
    select the granularity (``iteration_level`` etc.)."""
    from repro.serving import ContinuousBatcher

    lats_ms: list[float] = []
    comps_out: list = []
    tokens = 0

    async def go():
        nonlocal tokens
        loop = asyncio.get_running_loop()
        sem = asyncio.Semaphore(max(1, concurrency))
        rng = np.random.default_rng(seed)
        arrivals = None
        if open_rate > 0:
            gaps = rng.exponential(1.0 / open_rate, size=len(requests))
            arrivals = np.cumsum(gaps)

        async with ContinuousBatcher(server, max_batch=max_batch,
                                     slots=slots, max_wait_ms=max_wait_ms,
                                     **batcher_kwargs) as batcher:
            t0 = loop.time()

            async def one(i, r):
                nonlocal tokens
                t_issue = None
                if arrivals is not None:
                    await asyncio.sleep(max(0.0, arrivals[i]
                                            - (loop.time() - t0)))
                    t_issue = loop.time()   # open loop: latency from ARRIVAL
                async with sem:
                    if t_issue is None:     # closed loop: from the client's turn
                        t_issue = loop.time()
                    comp = await batcher.submit(r)
                    lats_ms.append((loop.time() - t_issue) * 1000.0)
                    comps_out.append(comp)
                    tokens += len(comp.tokens)

            await asyncio.gather(*[one(i, r) for i, r in enumerate(requests)])
            wall = loop.time() - t0
            return wall, batcher.stats.summary()

    wall, sched = asyncio.run(go())
    ttfts, tpots = _token_metrics(comps_out, lats_ms)
    out = summarize(lats_ms, wall, len(requests), tokens, ttfts, tpots)
    out["scheduler"] = sched
    return out


# -------------------------------------------------------------- fleet ----

def bench_fleet(server, requests, *, concurrency: int, open_rate: float = 0.0,
                seed: int = 0, **fleet_kwargs) -> dict:
    """Same client loops as :func:`bench_continuous`, but requests go
    through a :class:`~repro.fleet.FleetRouter` — N members, each with its
    own worker-resident arena, placed by the configured routing policy."""
    from repro.fleet import FleetRouter

    lats_ms: list[float] = []
    comps_out: list = []
    tokens = 0

    async def go():
        nonlocal tokens
        loop = asyncio.get_running_loop()
        sem = asyncio.Semaphore(max(1, concurrency))
        rng = np.random.default_rng(seed)
        arrivals = None
        if open_rate > 0:
            gaps = rng.exponential(1.0 / open_rate, size=len(requests))
            arrivals = np.cumsum(gaps)

        async with FleetRouter(server, **fleet_kwargs) as fleet:
            t0 = loop.time()

            async def one(i, r):
                nonlocal tokens
                t_issue = None
                if arrivals is not None:
                    await asyncio.sleep(max(0.0, arrivals[i]
                                            - (loop.time() - t0)))
                    t_issue = loop.time()   # open loop: latency from ARRIVAL
                async with sem:
                    if t_issue is None:
                        t_issue = loop.time()
                    comp = await fleet.submit(r)
                    lats_ms.append((loop.time() - t_issue) * 1000.0)
                    comps_out.append(comp)
                    tokens += len(comp.tokens)

            await asyncio.gather(*[one(i, r) for i, r in enumerate(requests)])
            wall = loop.time() - t0
            return wall, fleet.summary()

    wall, fleet_summary = asyncio.run(go())
    ttfts, tpots = _token_metrics(comps_out, lats_ms)
    out = summarize(lats_ms, wall, len(requests), tokens, ttfts, tpots)
    out["fleet"] = fleet_summary
    return out


# ------------------------------------------------------------------ run ----

MODES = ("waves", "continuous-batch", "continuous", "continuous-paged",
         "fleet")


def make_result(config: dict, results: dict) -> dict:
    """The ``--json`` document — stable schema for CI and plots."""
    # the A/B readings are meaningless without knowing how many cores the
    # fleet's workers shared — a 1-core host serializes the whole fleet
    doc = {"schema": "repro.serve_bench/v2",
           "config": dict(config, host_cpus=os.cpu_count()),
           "results": results}
    w = results.get("waves")
    cb = results.get("continuous-batch")
    c = results.get("continuous")
    if w and c:
        doc["speedup_continuous_vs_waves"] = round(
            c["throughput_rps"] / max(w["throughput_rps"], 1e-9), 3)
    if cb and c:
        # the ISSUE 5 acceptance number: iteration-level vs the PR 4
        # batch-level continuous baseline, same workload, same backend
        doc["speedup_iteration_vs_batch"] = round(
            c["throughput_rps"] / max(cb["throughput_rps"], 1e-9), 3)
        doc["ttft_p50_iteration_vs_batch_ms"] = [
            c.get("ttft_p50_ms"), cb.get("ttft_p50_ms")]
    cp = results.get("continuous-paged")
    if cp and c:
        # the ISSUE 7 acceptance pair: paged block-pool arena vs the slot
        # arena, same workload, same backend — plus the occupancy evidence
        # that shared prefixes really shared physical blocks
        doc["speedup_paged_vs_slot"] = round(
            cp["throughput_rps"] / max(c["throughput_rps"], 1e-9), 3)
        doc["ttft_p50_paged_vs_slot_ms"] = [
            cp.get("ttft_p50_ms"), c.get("ttft_p50_ms")]
        sched = cp.get("scheduler", {})
        doc["paged_occupancy_peaks"] = {
            k: sched.get(f"{k}_peak") for k in
            ("live_tokens", "allocated_blocks", "shared_blocks")}
    fl = results.get("fleet")
    fr = results.get("fleet-random")
    sg = results.get("single")
    if fl and sg:
        # the ISSUE 6 acceptance number: N members on N workers vs ONE
        # worker carrying the same total arena slots, same workload
        doc["fleet_speedup_vs_single"] = round(
            fl["throughput_rps"] / max(sg["throughput_rps"], 1e-9), 3)
    if fl and fr:
        # prefix-aware vs uniform-random placement, same fleet shape:
        # routed repeats skip prefill on the owning worker → lower TTFT
        doc["ttft_p50_prefix_vs_random_ms"] = [
            fl.get("ttft_p50_ms"), fr.get("ttft_p50_ms")]
    return doc


def run(backend: str = "threads", arch: str = "smollm-360m", *,
        requests: int = 64, concurrency: int = 32, prompt_len: int = 16,
        max_new: int = 32, wave: int = 8, slots: int = 4,
        max_wait_ms: float = 10.0, open_rate: float = 0.0,
        prefix_shared: float = 0.0, prefix_suffixes: int = 0,
        quantum: int = 8, prefix_tokens: int = 1 << 16,
        block_size: int = 16, os_threads: int = 8,
        modes=("waves", "continuous"),
        fleet: dict | None = None, seed: int = 0) -> dict:
    results: dict = {}
    config = {"backend": backend, "arch": arch, "requests": requests,
              "concurrency": concurrency, "prompt_len": prompt_len,
              "max_new": max_new, "wave_size": wave, "slots": slots,
              "max_wait_ms": max_wait_ms, "open_rate": open_rate,
              "prefix_shared": prefix_shared,
              "prefix_suffixes": prefix_suffixes, "quantum": quantum,
              "block_size": block_size}
    if "fleet" in modes:
        fleet = dict(fleet or {})
        fleet.setdefault("n", 3)
        fleet.setdefault("policy", "prefix")
        fleet.setdefault("elastic", True)
        fleet.setdefault("min", 1)
        fleet.setdefault("disaggregate", False)
        fleet.setdefault("prefill", 1)
        fleet.setdefault("paged", False)
        fleet.setdefault(
            "prefix_len",
            shared_prefix_len(prompt_len) if prefix_suffixes else None)
        config["fleet"] = dict(fleet)

    if "waves" in modes:
        cfg, session, server = make_server(backend, arch, max_new, os_threads)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed,
                                 prefix_shared, prefix_suffixes)
            warmup(server, cfg, max_new, prompt_len, wave)
            results["waves"] = bench_waves(server, reqs, wave_size=wave,
                                           slots=slots)
            results["waves"]["cost"] = session.cost.summary()
        finally:
            server.close()
            session.close()

    for mode in ("continuous-batch", "continuous", "continuous-paged"):
        if mode not in modes:
            continue
        # the async stack's client half: on the plain http backend swap in
        # the multiplexed asyncio client (same worker model, no thread per
        # in-flight request) — that pairing IS the async-serving story
        cont_backend = "http-aio" if backend == "http" else backend
        cfg, session, server = make_server(cont_backend, arch, max_new,
                                           os_threads)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed,
                                 prefix_shared, prefix_suffixes)
            warmup(server, cfg, max_new, prompt_len, wave)
            if mode == "continuous-batch":
                kwargs = {"iteration_level": False}
            else:
                kwargs = {"quantum": quantum,
                          "prompt_cap": max(prompt_len, 8),
                          "prefix_tokens": prefix_tokens}
                if mode == "continuous-paged":
                    kwargs.update(paged=True, block_size=block_size)
            if mode != "continuous-batch":
                warmup_iteration(server, cfg, max_new, prompt_len, wave,
                                 slots, **{k: v for k, v in kwargs.items()
                                           if k != "iteration_level"})
            results[mode] = bench_continuous(
                server, reqs, concurrency=concurrency, max_batch=wave,
                slots=slots, max_wait_ms=max_wait_ms, open_rate=open_rate,
                seed=seed, **kwargs)
            results[mode]["backend"] = cont_backend
            results[mode]["cost"] = session.cost.summary()
        finally:
            server.close()
            session.close()

    if "fleet" in modes:
        n = fleet["n"]
        common = dict(prefix_len=fleet["prefix_len"],
                      disaggregate=fleet["disaggregate"],
                      prefill_members=fleet["prefill"], max_batch=wave,
                      quantum=quantum, prompt_cap=max(prompt_len, 8),
                      prefix_tokens=prefix_tokens,
                      paged=fleet["paged"], block_size=block_size)
        # the A/B pair: the configured policy vs uniform-random placement
        # on an identical fleet — isolates what routing (not parallelism)
        # buys.  The elastic run is the one that records scale events.
        for key, policy, elastic in (
                ("fleet", fleet["policy"], fleet["elastic"]),
                ("fleet-random", "random", False)):
            # the router provisions workers as members spawn — start at 1
            cfg, session, server = make_server(backend, arch, max_new, 1)
            try:
                reqs = make_requests(cfg, requests, prompt_len, max_new,
                                     seed, prefix_shared, prefix_suffixes)
                warmup(server, cfg, max_new, prompt_len, wave)
                warmup_fleet(server, cfg, max_new, prompt_len, wave, n,
                             policy=policy, seed=seed, **common)
                results[key] = bench_fleet(
                    server, reqs, concurrency=concurrency, n_members=n,
                    policy=policy, elastic=elastic,
                    min_members=fleet["min"], open_rate=open_rate,
                    seed=seed, **common)
                results[key]["backend"] = backend
                results[key]["cost"] = session.cost.summary()
                results[key]["workers"] = worker_utilization(session)
            finally:
                server.close()
                session.close()
        # single-worker baseline at EQUAL TOTAL SLOTS: the same n arenas ×
        # wave rows, all affinity-pinned onto one worker
        cfg, session, server = make_server(backend, arch, max_new, 1)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed,
                                 prefix_shared, prefix_suffixes)
            warmup(server, cfg, max_new, prompt_len, wave)
            kwargs = dict(quantum=quantum, prompt_cap=max(prompt_len, 8),
                          prefix_tokens=prefix_tokens)
            warmup_iteration(server, cfg, max_new, prompt_len, wave, n,
                             **kwargs)
            results["single"] = bench_continuous(
                server, reqs, concurrency=concurrency, max_batch=wave,
                slots=n, max_wait_ms=max_wait_ms, open_rate=open_rate,
                seed=seed, iteration_level=True, **kwargs)
            results["single"]["backend"] = backend
            results["single"]["cost"] = session.cost.summary()
            results["single"]["workers"] = worker_utilization(session)
        finally:
            server.close()
            session.close()

    return make_result(config, results)


# ------------------------------------------------------------- chaos ----

CHAOS_KINDS = ("kill-member", "drop-conn", "stall", "expire-lease")


def make_chaos_plan(kind: str, *, seed: int, n_slots: int,
                    after: int | None = None):
    """One seeded ChaosPlan per CLI kind — same (slot, Nth-invoke)
    derivation for every kind so seeds compare across failure modes."""
    from repro.runtime.sandbox import ChaosEvent, ChaosPlan
    if kind == "kill-member":
        return ChaosPlan.kill_member(seed=seed, n_slots=n_slots, after=after)
    rng = random.Random(seed * 1_000_003 + 17)
    slot = rng.randrange(max(1, n_slots))
    fire = after if after is not None else 3 + rng.randrange(3)
    if kind == "drop-conn":
        ev = ChaosEvent("drop", slot=slot, after=fire)
    elif kind == "stall":
        ev = ChaosEvent("stall", slot=slot, after=fire, stall_s=0.25)
    elif kind == "expire-lease":
        ev = ChaosEvent("expire", slot=slot, after=fire)
    else:
        raise ValueError(f"unknown chaos kind {kind!r} "
                         f"(one of {CHAOS_KINDS})")
    return ChaosPlan([ev], seed=seed)


def run_chaos(backend: str = "processes", arch: str = "smollm-360m", *,
              kind: str = "kill-member", requests: int = 12,
              concurrency: int = 8, prompt_len: int = 16, max_new: int = 16,
              wave: int = 4, quantum: int = 4, prefix_tokens: int = 1 << 16,
              n_members: int = 2, after: int | None = None,
              seed: int = 7) -> dict:
    """The chaos drill: a non-elastic fleet of ``n_members`` on a real
    transport, one seeded failure injected mid-run, everything recorded.

    The contract under test: a killed worker is *added latency*, not a
    client-visible error — the victim's live rows replay (prompt +
    generated-so-far) on a surviving member and finish bit-identical,
    while the dispatcher's backoff policy spaces the retries and the
    transport lazily respawns the dead worker.  ``all_served`` and the
    event counts in the returned document are what CI asserts."""
    from repro.fleet import FleetRouter

    plan = make_chaos_plan(kind, seed=seed, n_slots=n_members, after=after)
    cfg, session, server = make_server(backend, arch, max_new, 1, chaos=plan)
    try:
        reqs = make_requests(cfg, requests, prompt_len, max_new, seed)
        common = dict(max_batch=wave, quantum=quantum,
                      prompt_cap=max(prompt_len, 8),
                      prefix_tokens=prefix_tokens)
        warmup(server, cfg, max_new, prompt_len, wave)
        warmup_fleet(server, cfg, max_new, prompt_len, wave, n_members,
                     policy="prefix", seed=seed, **common)
        plan.arm()                      # warmup traffic cost no chaos budget

        lats_ms: list[float] = []
        comps: list = []
        errors: list[str] = []

        async def go():
            loop = asyncio.get_running_loop()
            sem = asyncio.Semaphore(max(1, concurrency))
            async with FleetRouter(server, n_members=n_members,
                                   policy="prefix", elastic=False,
                                   seed=seed, **common) as fleet:
                t0 = loop.time()

                async def one(r):
                    async with sem:
                        t_issue = loop.time()
                        try:
                            comp = await fleet.submit(r)
                        except Exception as e:   # the drill records, CI asserts
                            errors.append(repr(e))
                            return
                        lats_ms.append((loop.time() - t_issue) * 1000.0)
                        comps.append(comp)

                await asyncio.gather(*[one(r) for r in reqs])
                return loop.time() - t0, fleet.summary()

        wall, fleet_summary = asyncio.run(go())
        retry_log = [dict(e) for e in session.retry_log]
        try:
            respawns = session.stats().get("respawns")
        except Exception:
            respawns = None
    finally:
        server.close()
        session.close()

    recovered = [(c, l) for c, l in zip(comps, lats_ms)
                 if getattr(c, "recovered", False)]
    untouched = [(c, l) for c, l in zip(comps, lats_ms)
                 if not getattr(c, "recovered", False)]
    # per-row receipts next to the transport's worker.* events — one
    # row.recovered per completion that survived a failover
    row_events = [{"action": "row.recovered", "tokens": len(c.tokens)}
                  for c, _ in recovered]
    counts = plan.counts()
    counts["row.recovered"] = len(row_events)
    tokens = sum(len(c.tokens) for c in comps)
    ttfts, tpots = _token_metrics(comps, lats_ms)
    result = summarize(lats_ms, wall, len(comps), tokens, ttfts, tpots)
    recovery: dict = {
        "recovered_rows": fleet_summary["batcher"].get("recovered_rows", 0),
        "fleet_recoveries": fleet_summary.get("recoveries", 0),
        "n_recovered": len(recovered), "n_untouched": len(untouched)}
    if recovered:
        recovery["recovered_latency"] = {
            k: round(v, 2)
            for k, v in percentiles([l for _, l in recovered]).items()}
    if untouched:
        recovery["untouched_latency"] = {
            k: round(v, 2)
            for k, v in percentiles([l for _, l in untouched]).items()}
    return {
        "schema": "repro.serve_chaos/v1",
        "config": {"backend": backend, "arch": arch, "requests": requests,
                   "concurrency": concurrency, "prompt_len": prompt_len,
                   "max_new": max_new, "wave_size": wave, "quantum": quantum,
                   "n_members": n_members, "chaos": kind, "seed": seed,
                   "host_cpus": os.cpu_count()},
        "plan": [{"kind": e.kind, "slot": e.slot, "after": e.after}
                 for e in plan.events],
        "events": plan.log() + row_events,
        "counts": counts,
        "all_served": not errors and len(comps) == len(reqs),
        "client_errors": errors,
        "worker_respawns": respawns,
        "result": result,
        "recovery": recovery,
        "retry_log": retry_log,
        "fleet": fleet_summary,
    }


def main(argv=None):
    from repro.cloud import available_backends
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--wave", type=int, default=8,
                    help="wave size / continuous max_batch / arena rows")
    ap.add_argument("--slots", type=int, default=4,
                    help="in-flight batches (batch modes) / arenas (iteration)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--open-rate", type=float, default=0.0,
                    help="req/s Poisson arrivals (0 = closed loop)")
    ap.add_argument("--prefix-shared", type=float, default=0.0,
                    help="fraction of requests carrying one shared prompt "
                         "(prefix-cache workload)")
    ap.add_argument("--prefix-suffixes", type=int, default=0,
                    help="shared requests carry the shared SYSTEM PREFIX "
                         "plus one of this many user suffixes (0 = whole "
                         "prompt identical)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="run fleet mode with N members (adds the fleet / "
                         "fleet-random / single results and A/B numbers)")
    ap.add_argument("--fleet-policy", default="prefix",
                    choices=("prefix", "p2c", "random", "radix"))
    ap.add_argument("--fleet-elastic", default="on", choices=("on", "off"),
                    help="elastic pool: start at --fleet-min, grow under "
                         "backlog, drain on low occupancy")
    ap.add_argument("--fleet-min", type=int, default=1)
    ap.add_argument("--fleet-disaggregate", default="off",
                    choices=("on", "off"),
                    help="split members into prefill/decode roles with row "
                         "migration over CONTROL frames")
    ap.add_argument("--fleet-prefill", type=int, default=1,
                    help="prefill members in disaggregated mode")
    ap.add_argument("--quantum", type=int, default=8,
                    help="iteration mode: decode steps per chunk")
    ap.add_argument("--prefix-tokens", type=int, default=1 << 16,
                    help="iteration mode: prefix-cache budget (0 disables)")
    ap.add_argument("--paged", default="off", choices=("on", "off"),
                    help="add the continuous-paged mode (block-pool KV "
                         "arena with radix prefix sharing, ISSUE 7)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged mode: KV block granularity (pow2-rounded)")
    ap.add_argument("--chaos", default="off",
                    choices=("off",) + CHAOS_KINDS,
                    help="run the seeded chaos drill instead of the normal "
                         "modes (writes repro.serve_chaos/v1)")
    ap.add_argument("--chaos-after", type=int, default=None,
                    help="fire on the Nth armed invocation of the victim "
                         "slot (default: seed-derived)")
    ap.add_argument("--chaos-members", type=int, default=2,
                    help="fleet size for the chaos drill")
    ap.add_argument("--os-threads", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--modes", default="waves,continuous",
                    help=f"comma list from {MODES}")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the repro.serve_bench/v2 document here")
    ap.add_argument("--trace", dest="trace_path", default=None,
                    help="record request spans and write Chrome-trace JSON "
                         "here (open in chrome://tracing or Perfetto)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    help="fraction of requests to trace (default 1.0 when "
                         "--trace is given, else 0 = off)")
    args = ap.parse_args(argv)

    if args.trace_path or args.trace_sample is not None:
        from repro.obs import trace as obs_trace
        obs_trace.configure(sample=(args.trace_sample
                                    if args.trace_sample is not None
                                    else 1.0))

    if args.chaos != "off":
        doc = run_chaos(args.backend, args.arch, kind=args.chaos,
                        requests=args.requests, concurrency=args.concurrency,
                        prompt_len=args.prompt_len, max_new=args.max_new,
                        wave=args.wave, quantum=args.quantum,
                        prefix_tokens=args.prefix_tokens,
                        n_members=args.chaos_members,
                        after=args.chaos_after, seed=args.seed)
        text = json.dumps(doc, indent=1)
        print(text)
        if args.json_path:
            with open(args.json_path, "w") as f:
                f.write(text + "\n")
        return

    modes = tuple(m for m in args.modes.split(",") if m)
    if args.paged == "on" and "continuous-paged" not in modes:
        modes = modes + ("continuous-paged",)
    fleet = None
    if args.fleet > 0:
        if "fleet" not in modes:
            modes = modes + ("fleet",)
        fleet = {"n": args.fleet, "policy": args.fleet_policy,
                 "elastic": args.fleet_elastic == "on",
                 "min": args.fleet_min,
                 "disaggregate": args.fleet_disaggregate == "on",
                 "prefill": args.fleet_prefill,
                 "paged": args.paged == "on"}
    doc = run(args.backend, args.arch, requests=args.requests,
              concurrency=args.concurrency, prompt_len=args.prompt_len,
              max_new=args.max_new, wave=args.wave, slots=args.slots,
              max_wait_ms=args.max_wait_ms, open_rate=args.open_rate,
              prefix_shared=args.prefix_shared,
              prefix_suffixes=args.prefix_suffixes, quantum=args.quantum,
              prefix_tokens=args.prefix_tokens, block_size=args.block_size,
              os_threads=args.os_threads, modes=modes, fleet=fleet,
              seed=args.seed)
    text = json.dumps(doc, indent=1)
    print(text)
    if args.json_path:
        with open(args.json_path, "w") as f:
            f.write(text + "\n")
    if args.trace_path:
        from repro.obs import trace as obs_trace
        n = obs_trace.TRACER.dump(args.trace_path)
        print(f"trace: {n} span events -> {args.trace_path}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
