"""Serving load generator: sync-waves vs async-continuous, side by side.

  PYTHONPATH=src python -m benchmarks.serve_bench \
      [--backend threads|processes|http|...] [--requests 48] \
      [--concurrency 32] [--open-rate 0] [--json BENCH_serving.json]

Closed loop (default): ``--concurrency`` clients each keep one request
outstanding until ``--requests`` total have completed — the paper's
fork-join client turned into sustained traffic.  Open loop
(``--open-rate`` req/s): Poisson arrivals, latency includes queueing the
way a real client sees it.

Two schedulers over the *same* pack/dispatch/unpack core:

* ``waves``      — ``LMServer.serve``: fixed fork-join partition into
                   ``--wave``-sized batches, ``--slots`` in flight (the
                   sync client: blocking threads).
* ``continuous`` — ``repro.serving.ContinuousBatcher`` on an event loop:
                   arriving requests admitted into decode slots as they
                   free, bucketed by decode length.  On the ``http``
                   backend the client side is the multiplexed
                   ``http-aio`` asyncio client (paper-style
                   conns × streams, no thread per request).

Requests are *long-tail mixed* on both axes: decode lengths (~3/4 short
at ``max_new/8``, ~1/4 long at ``--max-new``) and prompt lengths (~3/4 at
``prompt_len/4``, ~1/4 at ``--prompt-len``) — the workload where fixed
waves pay the long-neighbour tax and continuous batching shows up in
throughput.  Ragged packing is exact: pad masks run prefill-to-decode, so
the numbers are honest for mixed-length traffic.

``--json`` writes the machine-readable ``repro.serve_bench/v1`` schema
(see ``make_result``); CI's serving smoke step runs a tiny instance on
every push.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np


# ------------------------------------------------------------- workload ----

def make_requests(cfg, n: int, prompt_len: int, max_new: int, seed: int = 0):
    """Long-tail request mix on BOTH axes: ~3/4 short, ~1/4 long, for the
    prompt length and (independently) the decode length.

    The production-shaped workload: most prompts and completions are
    short, a tail is long.  Ragged prompt lengths are honest now — packing
    is pad-masked end to end (pack_prompts lengths → prefill/decode
    masks), so a mixed batch returns the same tokens each request would
    get alone.  Arrival-order waves almost always contain one long
    request, so every member decodes the full tail; length-bucketed
    continuous batches mostly decode short — that delta is the throughput
    story.
    """
    from repro.runtime.server import Request
    rng = np.random.default_rng(seed)
    short_new = max(1, max_new // 8)
    short_prompt = max(1, prompt_len // 4)
    return [Request(
        prompt=list(rng.integers(1, cfg.vocab_size,
                                 (short_prompt if rng.random() < 0.75
                                  else prompt_len))),
        max_new=(short_new if rng.random() < 0.75 else max_new))
        for _ in range(n)]


def make_server(backend: str, arch: str, max_new: int, os_threads: int):
    import jax
    from repro.cloud import Session
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.runtime.server import LMServer

    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(backend, os_threads=os_threads)
    server = LMServer(cfg, params, session=session, max_new=max_new)
    return cfg, session, server


def warmup(server, cfg, max_new: int, prompt_len: int, batch: int) -> None:
    """Pay every decode bucket's AOT compile at the *real* packed shapes
    (batch/prompt shape buckets, short AND long prompt buckets — the
    long-tail mix produces both) before timing anything."""
    from repro.runtime.server import Request, decode_bucket, shape_bucket
    for plen in sorted({shape_bucket(max(1, prompt_len // 4)),
                        shape_bucket(prompt_len)}):
        prompt = list(range(1, plen + 1))
        for b in sorted({decode_bucket(max(1, max_new // 8)),
                         decode_bucket(max_new)}):
            server.serve_wave([Request(prompt=prompt, max_new=b)] * batch)


def percentiles(lats_ms: list[float]) -> dict:
    a = np.asarray(lats_ms, dtype=np.float64)
    return {"p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean())}


def summarize(lats_ms: list[float], wall_s: float, n_requests: int,
              tokens: int) -> dict:
    out = {"requests": n_requests, "wall_s": round(wall_s, 3),
           "throughput_rps": round(n_requests / wall_s, 3),
           "tokens_per_s": round(tokens / wall_s, 3)}
    out.update({k: round(v, 2) for k, v in percentiles(lats_ms).items()})
    return out


# ----------------------------------------------------------- sync waves ----

def bench_waves(server, requests, *, wave_size: int, slots: int) -> dict:
    """Fixed fork-join: all requests present at t0, ``wave_size`` batches,
    ``slots`` waves in flight; a request's client-observed latency is its
    wave's completion time (the whole wave joins before anyone unpacks)."""
    waves = [requests[i:i + wave_size]
             for i in range(0, len(requests), wave_size)]
    t0 = time.perf_counter()
    futs, done_at = [], [0.0] * len(waves)

    def settle(i):
        futs[i].result()
        done_at[i] = time.perf_counter() - t0

    for i, w in enumerate(waves):
        if i >= slots:
            settle(i - slots)              # free the oldest payload
        futs.append(server.submit_wave(w, min_rows=wave_size))
    for i in range(max(0, len(waves) - slots), len(waves)):
        settle(i)
    comps = []
    for w, f in zip(waves, futs):
        comps.extend(server.unpack_wave(w, f))
    wall = time.perf_counter() - t0
    lats = [done_at[i // wave_size] * 1000.0 for i in range(len(requests))]
    tokens = sum(len(c.tokens) for c in comps)
    return summarize(lats, wall, len(requests), tokens)


# ----------------------------------------------------- async continuous ----

def bench_continuous(server, requests, *, concurrency: int, max_batch: int,
                     slots: int, max_wait_ms: float,
                     open_rate: float = 0.0, seed: int = 0) -> dict:
    """Closed loop (``open_rate==0``): ``concurrency`` clients back to
    back.  Open loop: Poisson arrivals at ``open_rate`` req/s, latency
    measured from *arrival* (queueing included)."""
    from repro.serving import ContinuousBatcher

    lats_ms: list[float] = []
    tokens = 0

    async def go():
        nonlocal tokens
        loop = asyncio.get_running_loop()
        sem = asyncio.Semaphore(max(1, concurrency))
        rng = np.random.default_rng(seed)
        arrivals = None
        if open_rate > 0:
            gaps = rng.exponential(1.0 / open_rate, size=len(requests))
            arrivals = np.cumsum(gaps)

        async with ContinuousBatcher(server, max_batch=max_batch,
                                     slots=slots,
                                     max_wait_ms=max_wait_ms) as batcher:
            t0 = loop.time()

            async def one(i, r):
                nonlocal tokens
                t_issue = None
                if arrivals is not None:
                    await asyncio.sleep(max(0.0, arrivals[i]
                                            - (loop.time() - t0)))
                    t_issue = loop.time()   # open loop: latency from ARRIVAL
                async with sem:
                    if t_issue is None:     # closed loop: from the client's turn
                        t_issue = loop.time()
                    comp = await batcher.submit(r)
                    lats_ms.append((loop.time() - t_issue) * 1000.0)
                    tokens += len(comp.tokens)

            await asyncio.gather(*[one(i, r) for i, r in enumerate(requests)])
            wall = loop.time() - t0
            return wall, batcher.stats.summary()

    wall, sched = asyncio.run(go())
    out = summarize(lats_ms, wall, len(requests), tokens)
    out["scheduler"] = sched
    return out


# ------------------------------------------------------------------ run ----

def make_result(config: dict, results: dict) -> dict:
    """The ``--json`` document — stable schema for CI and plots."""
    doc = {"schema": "repro.serve_bench/v1", "config": config,
           "results": results}
    w, c = results.get("waves"), results.get("continuous")
    if w and c:
        doc["speedup_continuous_vs_waves"] = round(
            c["throughput_rps"] / max(w["throughput_rps"], 1e-9), 3)
    return doc


def run(backend: str = "threads", arch: str = "smollm-360m", *,
        requests: int = 64, concurrency: int = 32, prompt_len: int = 16,
        max_new: int = 32, wave: int = 8, slots: int = 4,
        max_wait_ms: float = 10.0, open_rate: float = 0.0,
        os_threads: int = 8, modes=("waves", "continuous"),
        seed: int = 0) -> dict:
    results: dict = {}
    config = {"backend": backend, "arch": arch, "requests": requests,
              "concurrency": concurrency, "prompt_len": prompt_len,
              "max_new": max_new, "wave_size": wave, "slots": slots,
              "max_wait_ms": max_wait_ms, "open_rate": open_rate}

    if "waves" in modes:
        cfg, session, server = make_server(backend, arch, max_new, os_threads)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed)
            warmup(server, cfg, max_new, prompt_len, wave)
            results["waves"] = bench_waves(server, reqs, wave_size=wave,
                                           slots=slots)
            results["waves"]["cost"] = session.cost.summary()
        finally:
            server.close()
            session.close()

    if "continuous" in modes:
        # the async stack's client half: on the plain http backend swap in
        # the multiplexed asyncio client (same worker model, no thread per
        # in-flight request) — that pairing IS the async-serving story
        cont_backend = "http-aio" if backend == "http" else backend
        cfg, session, server = make_server(cont_backend, arch, max_new,
                                           os_threads)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed)
            warmup(server, cfg, max_new, prompt_len, wave)
            results["continuous"] = bench_continuous(
                server, reqs, concurrency=concurrency, max_batch=wave,
                slots=slots, max_wait_ms=max_wait_ms, open_rate=open_rate,
                seed=seed)
            results["continuous"]["backend"] = cont_backend
            results["continuous"]["cost"] = session.cost.summary()
        finally:
            server.close()
            session.close()

    return make_result(config, results)


def main(argv=None):
    from repro.cloud import available_backends
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--wave", type=int, default=8,
                    help="wave size / continuous max_batch")
    ap.add_argument("--slots", type=int, default=4,
                    help="in-flight batches, both modes")
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--open-rate", type=float, default=0.0,
                    help="req/s Poisson arrivals (0 = closed loop)")
    ap.add_argument("--os-threads", type=int, default=8)
    ap.add_argument("--modes", default="waves,continuous")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the repro.serve_bench/v1 document here")
    args = ap.parse_args(argv)

    doc = run(args.backend, args.arch, requests=args.requests,
              concurrency=args.concurrency, prompt_len=args.prompt_len,
              max_new=args.max_new, wave=args.wave, slots=args.slots,
              max_wait_ms=args.max_wait_ms, open_rate=args.open_rate,
              os_threads=args.os_threads,
              modes=tuple(args.modes.split(",")))
    text = json.dumps(doc, indent=1)
    print(text)
    if args.json_path:
        with open(args.json_path, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
