"""Serving load generator: waves vs batch-level vs iteration-level.

  PYTHONPATH=src python -m benchmarks.serve_bench \
      [--backend threads|processes|http|...] [--requests 48] \
      [--concurrency 32] [--open-rate 0] [--prefix-shared 0.5] \
      [--json BENCH_serving.json]

Closed loop (default): ``--concurrency`` clients each keep one request
outstanding until ``--requests`` total have completed — the paper's
fork-join client turned into sustained traffic.  Open loop
(``--open-rate`` req/s): Poisson arrivals, latency includes queueing the
way a real client sees it.

Three schedulers over the *same* model entry points:

* ``waves``            — ``LMServer.serve``: fixed fork-join partition
                         into ``--wave``-sized batches.
* ``continuous-batch`` — ``ContinuousBatcher`` pinned to the PR 4
                         batch-level path (``iteration_level=False``):
                         slot admission *between* batches, every batch
                         re-runs prefill.
* ``continuous``       — the ISSUE 5 iteration-level path where the
                         backend supports worker-resident state: KV cache
                         arenas live on the workers, admission every
                         ``--quantum`` decode steps, eviction at
                         ``max_new`` without batch-tail wait, and a
                         worker-resident prompt-prefix cache that lets
                         repeated prompts skip prefill entirely.

Requests are *long-tail mixed* on both axes (decode ~3/4 short at
``max_new/8``; prompts ~3/4 short at ``prompt_len/4``), and
``--prefix-shared`` replaces that fraction of prompts with one shared
system prompt of length ``--prompt-len`` — the workload where prefix
reuse shows up.  Reported per mode: throughput, completion-latency
percentiles, **TTFT** percentiles (time to first token — batch-level
schedulers have no token stream, so their TTFT *is* the completion
latency) and **TPOT** (time per output token after the first).

``--json`` writes the machine-readable ``repro.serve_bench/v2`` schema
(see ``make_result``); CI's serving smoke steps run tiny instances on
every push.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import numpy as np


# ------------------------------------------------------------- workload ----

def make_requests(cfg, n: int, prompt_len: int, max_new: int, seed: int = 0,
                  prefix_shared: float = 0.0):
    """Long-tail request mix on BOTH axes: ~3/4 short, ~1/4 long, for the
    prompt length and (independently) the decode length; ``prefix_shared``
    of the requests instead carry one identical shared prompt (the
    system-prompt pattern the prefix cache exists for)."""
    from repro.runtime.server import Request
    rng = np.random.default_rng(seed)
    short_new = max(1, max_new // 8)
    short_prompt = max(1, prompt_len // 4)
    shared = list(rng.integers(1, cfg.vocab_size, prompt_len))
    out = []
    for _ in range(n):
        if prefix_shared > 0 and rng.random() < prefix_shared:
            prompt = list(shared)
        else:
            prompt = list(rng.integers(
                1, cfg.vocab_size,
                (short_prompt if rng.random() < 0.75 else prompt_len)))
        out.append(Request(
            prompt=prompt,
            max_new=(short_new if rng.random() < 0.75 else max_new)))
    return out


def make_server(backend: str, arch: str, max_new: int, os_threads: int):
    import jax
    from repro.cloud import Session
    from repro.configs import get_smoke
    from repro.models import build_model
    from repro.runtime.server import LMServer

    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(backend, os_threads=os_threads)
    server = LMServer(cfg, params, session=session, max_new=max_new)
    return cfg, session, server


def warmup(server, cfg, max_new: int, prompt_len: int, batch: int) -> None:
    """Pay every decode bucket's AOT compile at the *real* packed shapes
    (batch/prompt shape buckets, short AND long prompt buckets — the
    long-tail mix produces both) before timing anything."""
    from repro.runtime.server import Request, decode_bucket, shape_bucket
    for plen in sorted({shape_bucket(max(1, prompt_len // 4)),
                        shape_bucket(prompt_len)}):
        prompt = list(range(1, plen + 1))
        for b in sorted({decode_bucket(max(1, max_new // 8)),
                         decode_bucket(max_new)}):
            server.serve_wave([Request(prompt=prompt, max_new=b)] * batch)


def warmup_iteration(server, cfg, max_new: int, prompt_len: int, wave: int,
                     slots: int, **batcher_kwargs) -> None:
    """Untimed pass through the iteration-level scheduler itself: pays the
    engine entry points' jit compiles (prefill per prompt-width bucket,
    decode per chunk-length bucket) on the same affinity-pinned workers
    the timed run will use — the engine analogue of ``warmup``."""
    from repro.runtime.server import Request, shape_bucket
    from repro.serving import run_continuous
    reqs = []
    for plen in sorted({shape_bucket(max(1, prompt_len // 4)),
                        shape_bucket(prompt_len)}):
        for new in sorted({max(1, max_new // 8), max_new}):
            reqs.extend([Request(prompt=list(range(1, plen + 1)),
                                 max_new=new)] * wave)
    run_continuous(server, reqs, concurrency=wave * slots, max_batch=wave,
                   slots=slots, iteration_level=True, **batcher_kwargs)


def percentiles(lats_ms: list[float], prefix: str = "") -> dict:
    a = np.asarray(lats_ms, dtype=np.float64)
    return {f"{prefix}p50_ms": float(np.percentile(a, 50)),
            f"{prefix}p95_ms": float(np.percentile(a, 95)),
            f"{prefix}p99_ms": float(np.percentile(a, 99)),
            f"{prefix}mean_ms": float(a.mean())}


def summarize(lats_ms: list[float], wall_s: float, n_requests: int,
              tokens: int, ttfts_ms: list[float] | None = None,
              tpots_ms: list[float] | None = None) -> dict:
    out = {"requests": n_requests, "wall_s": round(wall_s, 3),
           "throughput_rps": round(n_requests / wall_s, 3),
           "tokens_per_s": round(tokens / wall_s, 3)}
    out.update({k: round(v, 2) for k, v in percentiles(lats_ms).items()})
    if ttfts_ms:
        out.update({k: round(v, 2)
                    for k, v in percentiles(ttfts_ms, "ttft_").items()})
    if tpots_ms:
        out.update({k: round(v, 3)
                    for k, v in percentiles(tpots_ms, "tpot_").items()})
    return out


def _token_metrics(comps, lats_ms):
    """Client-side TTFT/TPOT: completions carry ttft_ms where the
    scheduler streams (iteration-level); batch-level completions fall back
    to their completion latency — the honest number for a scheduler whose
    whole batch joins at once."""
    ttfts, tpots = [], []
    for comp, lat in zip(comps, lats_ms):
        ttft = comp.ttft_ms if comp.ttft_ms is not None else lat
        ttfts.append(ttft)
        n = len(comp.tokens)
        if n > 1:
            tpots.append(max(0.0, lat - ttft) / (n - 1))
    return ttfts, tpots


# ----------------------------------------------------------- sync waves ----

def bench_waves(server, requests, *, wave_size: int, slots: int) -> dict:
    """Fixed fork-join: all requests present at t0, ``wave_size`` batches,
    ``slots`` waves in flight; a request's client-observed latency is its
    wave's completion time (the whole wave joins before anyone unpacks)."""
    waves = [requests[i:i + wave_size]
             for i in range(0, len(requests), wave_size)]
    t0 = time.perf_counter()
    futs, done_at = [], [0.0] * len(waves)

    def settle(i):
        futs[i].result()
        done_at[i] = time.perf_counter() - t0

    for i, w in enumerate(waves):
        if i >= slots:
            settle(i - slots)              # free the oldest payload
        futs.append(server.submit_wave(w, min_rows=wave_size))
    for i in range(max(0, len(waves) - slots), len(waves)):
        settle(i)
    comps = []
    for w, f in zip(waves, futs):
        comps.extend(server.unpack_wave(w, f))
    wall = time.perf_counter() - t0
    lats = [done_at[i // wave_size] * 1000.0 for i in range(len(requests))]
    tokens = sum(len(c.tokens) for c in comps)
    ttfts, tpots = _token_metrics(comps, lats)
    return summarize(lats, wall, len(requests), tokens, ttfts, tpots)


# ----------------------------------------------------- async continuous ----

def bench_continuous(server, requests, *, concurrency: int, max_batch: int,
                     slots: int, max_wait_ms: float,
                     open_rate: float = 0.0, seed: int = 0,
                     **batcher_kwargs) -> dict:
    """Closed loop (``open_rate==0``): ``concurrency`` clients back to
    back.  Open loop: Poisson arrivals at ``open_rate`` req/s, latency
    measured from *arrival* (queueing included).  ``batcher_kwargs``
    select the granularity (``iteration_level`` etc.)."""
    from repro.serving import ContinuousBatcher

    lats_ms: list[float] = []
    comps_out: list = []
    tokens = 0

    async def go():
        nonlocal tokens
        loop = asyncio.get_running_loop()
        sem = asyncio.Semaphore(max(1, concurrency))
        rng = np.random.default_rng(seed)
        arrivals = None
        if open_rate > 0:
            gaps = rng.exponential(1.0 / open_rate, size=len(requests))
            arrivals = np.cumsum(gaps)

        async with ContinuousBatcher(server, max_batch=max_batch,
                                     slots=slots, max_wait_ms=max_wait_ms,
                                     **batcher_kwargs) as batcher:
            t0 = loop.time()

            async def one(i, r):
                nonlocal tokens
                t_issue = None
                if arrivals is not None:
                    await asyncio.sleep(max(0.0, arrivals[i]
                                            - (loop.time() - t0)))
                    t_issue = loop.time()   # open loop: latency from ARRIVAL
                async with sem:
                    if t_issue is None:     # closed loop: from the client's turn
                        t_issue = loop.time()
                    comp = await batcher.submit(r)
                    lats_ms.append((loop.time() - t_issue) * 1000.0)
                    comps_out.append(comp)
                    tokens += len(comp.tokens)

            await asyncio.gather(*[one(i, r) for i, r in enumerate(requests)])
            wall = loop.time() - t0
            return wall, batcher.stats.summary()

    wall, sched = asyncio.run(go())
    ttfts, tpots = _token_metrics(comps_out, lats_ms)
    out = summarize(lats_ms, wall, len(requests), tokens, ttfts, tpots)
    out["scheduler"] = sched
    return out


# ------------------------------------------------------------------ run ----

MODES = ("waves", "continuous-batch", "continuous")


def make_result(config: dict, results: dict) -> dict:
    """The ``--json`` document — stable schema for CI and plots."""
    doc = {"schema": "repro.serve_bench/v2", "config": config,
           "results": results}
    w = results.get("waves")
    cb = results.get("continuous-batch")
    c = results.get("continuous")
    if w and c:
        doc["speedup_continuous_vs_waves"] = round(
            c["throughput_rps"] / max(w["throughput_rps"], 1e-9), 3)
    if cb and c:
        # the ISSUE 5 acceptance number: iteration-level vs the PR 4
        # batch-level continuous baseline, same workload, same backend
        doc["speedup_iteration_vs_batch"] = round(
            c["throughput_rps"] / max(cb["throughput_rps"], 1e-9), 3)
        doc["ttft_p50_iteration_vs_batch_ms"] = [
            c.get("ttft_p50_ms"), cb.get("ttft_p50_ms")]
    return doc


def run(backend: str = "threads", arch: str = "smollm-360m", *,
        requests: int = 64, concurrency: int = 32, prompt_len: int = 16,
        max_new: int = 32, wave: int = 8, slots: int = 4,
        max_wait_ms: float = 10.0, open_rate: float = 0.0,
        prefix_shared: float = 0.0, quantum: int = 8,
        prefix_tokens: int = 1 << 16,
        os_threads: int = 8, modes=("waves", "continuous"),
        seed: int = 0) -> dict:
    results: dict = {}
    config = {"backend": backend, "arch": arch, "requests": requests,
              "concurrency": concurrency, "prompt_len": prompt_len,
              "max_new": max_new, "wave_size": wave, "slots": slots,
              "max_wait_ms": max_wait_ms, "open_rate": open_rate,
              "prefix_shared": prefix_shared, "quantum": quantum}

    if "waves" in modes:
        cfg, session, server = make_server(backend, arch, max_new, os_threads)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed,
                                 prefix_shared)
            warmup(server, cfg, max_new, prompt_len, wave)
            results["waves"] = bench_waves(server, reqs, wave_size=wave,
                                           slots=slots)
            results["waves"]["cost"] = session.cost.summary()
        finally:
            server.close()
            session.close()

    for mode in ("continuous-batch", "continuous"):
        if mode not in modes:
            continue
        # the async stack's client half: on the plain http backend swap in
        # the multiplexed asyncio client (same worker model, no thread per
        # in-flight request) — that pairing IS the async-serving story
        cont_backend = "http-aio" if backend == "http" else backend
        cfg, session, server = make_server(cont_backend, arch, max_new,
                                           os_threads)
        try:
            reqs = make_requests(cfg, requests, prompt_len, max_new, seed,
                                 prefix_shared)
            warmup(server, cfg, max_new, prompt_len, wave)
            kwargs = ({"iteration_level": False} if mode == "continuous-batch"
                      else {"quantum": quantum,
                            "prompt_cap": max(prompt_len, 8),
                            "prefix_tokens": prefix_tokens})
            if mode == "continuous":
                warmup_iteration(server, cfg, max_new, prompt_len, wave,
                                 slots, **{k: v for k, v in kwargs.items()
                                           if k != "iteration_level"})
            results[mode] = bench_continuous(
                server, reqs, concurrency=concurrency, max_batch=wave,
                slots=slots, max_wait_ms=max_wait_ms, open_rate=open_rate,
                seed=seed, **kwargs)
            results[mode]["backend"] = cont_backend
            results[mode]["cost"] = session.cost.summary()
        finally:
            server.close()
            session.close()

    return make_result(config, results)


def main(argv=None):
    from repro.cloud import available_backends
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--wave", type=int, default=8,
                    help="wave size / continuous max_batch / arena rows")
    ap.add_argument("--slots", type=int, default=4,
                    help="in-flight batches (batch modes) / arenas (iteration)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0)
    ap.add_argument("--open-rate", type=float, default=0.0,
                    help="req/s Poisson arrivals (0 = closed loop)")
    ap.add_argument("--prefix-shared", type=float, default=0.0,
                    help="fraction of requests carrying one shared prompt "
                         "(prefix-cache workload)")
    ap.add_argument("--quantum", type=int, default=8,
                    help="iteration mode: decode steps per chunk")
    ap.add_argument("--prefix-tokens", type=int, default=1 << 16,
                    help="iteration mode: prefix-cache budget (0 disables)")
    ap.add_argument("--os-threads", type=int, default=8)
    ap.add_argument("--modes", default="waves,continuous",
                    help=f"comma list from {MODES}")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the repro.serve_bench/v2 document here")
    args = ap.parse_args(argv)

    doc = run(args.backend, args.arch, requests=args.requests,
              concurrency=args.concurrency, prompt_len=args.prompt_len,
              max_new=args.max_new, wave=args.wave, slots=args.slots,
              max_wait_ms=args.max_wait_ms, open_rate=args.open_rate,
              prefix_shared=args.prefix_shared, quantum=args.quantum,
              prefix_tokens=args.prefix_tokens,
              os_threads=args.os_threads,
              modes=tuple(args.modes.split(",")))
    text = json.dumps(doc, indent=1)
    print(text)
    if args.json_path:
        with open(args.json_path, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
