"""Benchmark driver: one entry per paper table/figure + the roofline table.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Writes results to experiments/results/<name>.json and prints a summary.
(The dry-run/roofline source data comes from `python -m repro.launch.dryrun`;
this driver only assembles it.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)

    from . import (dispatch_bench, nqueens_bench, raytracer_bench,
                   roofline_table, serialization_bench, serve_bench)

    benches = {
        "serialization (paper Tables 9/10)": serialization_bench.run,
        "dispatch_latency (paper Fig 11)": dispatch_bench.run,
        "serving (waves vs continuous, ISSUE 3)":
            (lambda: serve_bench.run("threads", requests=16, concurrency=8,
                                     prompt_len=8, max_new=8, wave=4,
                                     slots=2, os_threads=4)) if args.quick
            else (lambda: serve_bench.run("http", requests=64,
                                          concurrency=32, max_new=32)),
        "nqueens (paper Figs 12/13)":
            (lambda: nqueens_bench.run(n=9, plist=(1, 2))) if args.quick
            else (lambda: nqueens_bench.run(n=12, plist=(1, 2))),
        "raytracer (paper Figs 1/14)":
            (lambda: raytracer_bench.run(width=48, spp=2, tiles=(24, 12)))
            if args.quick else raytracer_bench.run,
        "roofline (assigned archs, §Roofline)": roofline_table.run,
    }

    failures = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        print(f"\n=== {name} ===", flush=True)
        try:
            out = fn()
        except Exception as e:  # keep the suite running
            failures.append((name, repr(e)))
            print(f"FAILED: {e!r}")
            continue
        dt = time.perf_counter() - t0
        slug = name.split(" ")[0]
        with open(os.path.join(RESULTS, f"{slug}.json"), "w") as f:
            json.dump(out, f, indent=1, default=str)
        brief = {k: v for k, v in out.items()
                 if k in ("claims", "paper_claims", "cells_done",
                          "cells_missing", "bottleneck_histogram",
                          "real_burst_64", "serial_s", "solutions")}
        print(json.dumps(brief, indent=1, default=str))
        print(f"[{slug} done in {dt:.1f}s -> experiments/results/"
              f"{slug}.json]", flush=True)

    if failures:
        print("\nFAILURES:", failures)
        raise SystemExit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
