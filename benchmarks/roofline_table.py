"""Assemble the §Roofline table from the dry-run artifacts.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
the per-(arch × shape × mesh) table: three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, bytes/device — plus SKIP rows for the
long_500k cells of full-attention archs (DESIGN §Arch-applicability).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES, cells, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load(dryrun_dir: str = DRYRUN_DIR) -> dict:
    out = {}
    for path in glob.glob(os.path.join(dryrun_dir, "*.json")):
        with open(path) as f:
            rec = json.load(f)
        out[(rec["arch"], rec["cell"], rec["mesh"])] = rec
    return out


def table(dryrun_dir: str = DRYRUN_DIR, mesh: str = "16x16"):
    recs = load(dryrun_dir)
    rows = []
    for arch in ARCHS:
        arch_cells = cells(arch)
        for cell in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if cell not in arch_cells:
                rows.append({"arch": arch, "cell": cell, "skip":
                             "full-attention arch: O(S^2) at 524k excluded "
                             "by design"})
                continue
            rec = recs.get((arch, cell, mesh))
            if rec is None:
                rows.append({"arch": arch, "cell": cell,
                             "skip": "MISSING (dry-run not yet run)"})
                continue
            r = rec["roofline"]
            args_gib = (rec["memory_analysis"].get("argument_size_in_bytes")
                        or 0) / 2**30
            temp_gib = (rec["memory_analysis"].get("temp_size_in_bytes")
                        or 0) / 2**30
            rows.append({
                "arch": arch, "cell": cell,
                "t_compute_ms": r["t_compute_s"] * 1e3,
                "t_memory_ms": r["t_memory_s"] * 1e3,
                "t_collective_ms": r["t_collective_s"] * 1e3,
                "bottleneck": r["bottleneck"],
                "useful_flops_frac": rec.get("useful_flops_frac"),
                "args_gib_per_dev": args_gib,
                "temp_gib_per_dev": temp_gib,
                "compile_s": rec["compile_s"],
            })
    return rows


def markdown(rows) -> str:
    hdr = ("| arch | cell | compute ms | memory ms | collective ms | "
           "bottleneck | 6ND/HLO | args GiB/dev | temp GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['cell']} | — | — | — | "
                         f"SKIP | — | — | — |")
            continue
        uf = r["useful_flops_frac"]
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['t_compute_ms']:.2f} | "
            f"{r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} | "
            f"{r['bottleneck']} | {uf:.3f} | "
            f"{r['args_gib_per_dev']:.2f} | {r['temp_gib_per_dev']:.2f} |")
    return "\n".join(lines)


def run():
    rows = table()
    done = [r for r in rows if "skip" not in r]
    missing = [r for r in rows if r.get("skip", "").startswith("MISSING")]
    by_bottleneck = {}
    for r in done:
        by_bottleneck[r["bottleneck"]] = by_bottleneck.get(
            r["bottleneck"], 0) + 1
    return {"cells_done": len(done), "cells_missing": len(missing),
            "bottleneck_histogram": by_bottleneck,
            "markdown": markdown(rows)}


if __name__ == "__main__":
    out = run()
    print(out["markdown"])
    print(f"\ndone={out['cells_done']} missing={out['cells_missing']} "
          f"bottlenecks={out['bottleneck_histogram']}")
