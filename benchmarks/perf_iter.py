"""§Perf hillclimb driver: hypothesis → change → re-lower → compare.

Each variant = (name, hypothesis, cfg overrides, rule overrides).  The
driver compiles baseline + variants for one (arch × cell) on the single-pod
mesh and reports the three roofline terms side by side, appending to
experiments/perf/<arch>_<cell>.json so the iteration LOG (not just the
winner) is preserved for EXPERIMENTS.md §Perf.

Run me as:  PYTHONPATH=src python -m benchmarks.perf_iter --cell <name>
(this module sets the 512-device XLA flag itself, like dryrun).
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse    # noqa: E402
import json        # noqa: E402
import time        # noqa: E402

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "perf")


def run_variants(arch: str, cell: str, variants: list[dict],
                 include_baseline: bool = True) -> list[dict]:
    from repro.launch.dryrun import run_cell
    rows = []
    todo = ([{"name": "baseline", "hypothesis": "paper-faithful defaults",
              "cfg": {}, "rules": {}}] if include_baseline else []) + variants
    for v in todo:
        t0 = time.perf_counter()
        try:
            rec = run_cell(arch, cell, multi_pod=False, out_dir=None,
                           verbose=False, overrides={**v.get("cfg", {})},
                           rule_overrides=v.get("rules", {}))
            r = rec["roofline"]
            rows.append({
                "variant": v["name"], "hypothesis": v.get("hypothesis", ""),
                "t_compute_s": r["t_compute_s"],
                "t_memory_s": r["t_memory_s"],
                "t_collective_s": r["t_collective_s"],
                "bottleneck": r["bottleneck"],
                "useful_flops_frac": rec["useful_flops_frac"],
                "args_gib": (rec["memory_analysis"].get(
                    "argument_size_in_bytes") or 0) / 2**30,
                "temp_gib": (rec["memory_analysis"].get(
                    "temp_size_in_bytes") or 0) / 2**30,
                "collective_per_op": r["collective_per_op"],
                "compile_s": time.perf_counter() - t0,
            })
        except Exception as e:
            rows.append({"variant": v["name"], "error": repr(e)[:500]})
        print(json.dumps(rows[-1], indent=1, default=str), flush=True)

    os.makedirs(PERF_DIR, exist_ok=True)
    path = os.path.join(PERF_DIR, f"{arch}_{cell}.json")
    prior = []
    if os.path.exists(path):
        with open(path) as f:
            prior = json.load(f)
    with open(path, "w") as f:
        json.dump(prior + rows, f, indent=1, default=str)
    return rows


# ---- the three chosen cells and their iteration plans live in callers
# (see experiments/perf/*.py scripts written during §Perf iterations).

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variants", default="[]",
                    help="JSON list of {name,hypothesis,cfg,rules}")
    ap.add_argument("--no-baseline", action="store_true")
    args = ap.parse_args()
    run_variants(args.arch, args.cell, json.loads(args.variants),
                 include_baseline=not args.no_baseline)
