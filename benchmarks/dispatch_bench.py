"""Paper Fig 11: client-observed latency of concurrent warm invocations.

Real execution path: N no-op-ish tasks through the worker pool; the
calibrated latency model maps server durations to what an AWS client
observes.  Reproduces the figure's shape: ~50 ms single invocation, linear
growth to ~150 ms approaching the stream budget (16 conns × 100 streams),
then queueing; dispatch rate ~10 inv/ms.  Also contrasts the HTTP/1.1
per-request client (fd-limited, per-request handshake).

``sim_vs_real`` (ISSUE 2) runs the *same* burst on the ``sim-aws`` backend
(latency modeled) and the ``http`` backend (latency *measured* over a real
socket to a separately-spawned worker) and reports them side by side —
simulation turned into measurement, in the same record field.
"""
from __future__ import annotations

import numpy as np

from repro.cloud import Session
from repro.dispatch import DEFAULT_LATENCY


def noop_task(x):
    import jax.numpy as jnp
    return jnp.float32(x) + 1


def sim_vs_real(n: int = 32):
    """One burst, two clients: sim-aws (modeled) vs http (measured)."""
    out = {}
    for backend in ("sim-aws", "http"):
        try:
            with Session(backend, os_threads=8) as sess:
                f = sess.function(noop_task, name="noop_task", memory_mb=256)
                f.map([(float(i),) for i in range(n)])
                warm = f.map([(float(i),) for i in range(n)])
                assert [float(v) for v in warm] == [i + 1.0 for i in range(n)]
                lats = [r.modeled_latency_ms for r in sess.records[-n:]]
                out[backend] = {
                    "latency_source": ("measured"
                                       if sess.records[-1].latency_measured
                                       else "modeled"),
                    "warm_median_ms": float(np.median(lats)),
                    "warm_p95_ms": float(np.percentile(lats, 95)),
                    "warm_max_ms": float(np.max(lats)),
                    "cold_starts": sum(1 for r in sess.records
                                       if r.cold_start),
                }
        except Exception as e:             # http needs a spawnable worker
            out[backend] = {"error": f"{type(e).__name__}: {e}"}
    return out


def run(concurrencies=(1, 10, 50, 100, 400, 800, 1200, 1600, 2000),
        task_ms: float = 10.0):
    out = {"concurrency": list(concurrencies), "clients": {}}
    for client in ("http2_pool", "http1_per_request"):
        med, p95, makespan = [], [], []
        for k in concurrencies:
            durations = [task_ms] * k
            lats = DEFAULT_LATENCY.simulate_burst(durations, client=client)
            med.append(float(np.median(lats)))
            p95.append(float(np.percentile(lats, 95)))
            makespan.append(float(np.max(lats)))
        out["clients"][client] = {"median_ms": med, "p95_ms": p95,
                                  "makespan_ms": makespan}

    # paper's headline numbers for the pooled client
    h2 = out["clients"]["http2_pool"]
    single = h2["median_ms"][0]
    at_capacity = h2["median_ms"][list(concurrencies).index(1600)] \
        if 1600 in concurrencies else h2["median_ms"][-1]
    out["claims"] = {
        "single_warm_invocation_ms": single,
        "paper_single_warm_invocation_ms": 50.0,
        "near_capacity_ms": at_capacity,
        "paper_near_capacity_ms": 150.0,
        "dispatch_rate_per_ms": DEFAULT_LATENCY.dispatch_rate_per_ms,
        "paper_dispatch_rate_per_ms": 10.0,
    }

    # real end-to-end micro-burst on the "sim-aws" backend (execution is
    # real, every record stamped with modeled client-observed latency)
    with Session("sim-aws") as sess:
        noop = sess.function(lambda x: x + 1, name="noop", memory_mb=256)
        noop.map([(np.float32(i),) for i in range(64)])
        lats = sess.modeled_latencies_ms()
        per_record = [r.modeled_latency_ms for r in sess.records]
        out["real_burst_64"] = {
            "median_ms": float(np.median(lats)),
            "max_ms": float(np.max(lats)),
            "median_per_record_ms": float(np.median(per_record)),
            "invocations": sess.cost.invocations,
        }

    # ISSUE 2: the same burst through the modeled client and the real one
    out["sim_vs_real"] = sim_vs_real()
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
