"""Deterministic synthetic LM data pipeline, shardable across hosts.

Batches are a pure function of (seed, step) via numpy Philox — restart at
step k reproduces exactly the stream a failure interrupted (the skip-ahead
property checkpoint/restart depends on; no data-loader state to snapshot).
Each host materializes only its slice; `device_batch` places the global
array on the mesh with the production batch sharding.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so loss can actually fall: next token depends on
    # the previous one through a fixed permutation + noise
    noise: float = 0.1

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.seed, counter=step))

    def batch(self, step: int, *, lo: int = 0, hi: int | None = None):
        """Rows [lo, hi) of the global batch for ``step``."""
        hi = self.global_batch if hi is None else hi
        rng = self._rng(step)
        perm = np.random.Generator(np.random.Philox(key=self.seed ^ 0xABCD,
                                                    counter=0)).permutation(
            self.vocab_size)
        tokens = rng.integers(0, self.vocab_size,
                              (self.global_batch, self.seq_len + 1),
                              dtype=np.int32)
        # structured continuation: with prob 1-noise, t[i+1] = perm[t[i]]
        follow = rng.random((self.global_batch, self.seq_len)) > self.noise
        for i in range(1, self.seq_len + 1):
            tokens[:, i] = np.where(follow[:, i - 1],
                                    perm[tokens[:, i - 1]], tokens[:, i])
        tokens = tokens[lo:hi]
        return {"tokens": tokens[:, :-1],
                "labels": tokens[:, 1:].astype(np.int32)}

    def host_batch(self, step: int, host_id: int, num_hosts: int):
        per = self.global_batch // num_hosts
        return self.batch(step, lo=host_id * per, hi=(host_id + 1) * per)

    def device_batch(self, step: int, mesh, rules=None):
        """Global batch placed with the production sharding."""
        b = self.batch(step)
        if rules is not None:
            tok_sh = rules.sharding(("act_batch", "act_seq"),
                                    b["tokens"].shape)
        else:
            axes = tuple(a for a in ("pod", "data")
                         if a in mesh.axis_names) or None
            tok_sh = NamedSharding(mesh, P(axes))
        return {k: jax.device_put(v, tok_sh) for k, v in b.items()}
