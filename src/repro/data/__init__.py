from .pipeline import SyntheticLM
