"""Sharded checkpointing over the repro binary archive.

Layout:  <dir>/step_<k>/shard_<i>.bin + manifest.json + COMMITTED

* every leaf is serialized with the paper-calibrated `binary` archive
  (serialization/), optionally zlib-compressed;
* a checkpoint is visible only after the COMMITTED marker is atomically
  renamed into place — a killed writer never yields a half checkpoint;
* `AsyncCheckpointer` snapshots to host memory synchronously (device->host
  copy) and writes in a background thread, so the train loop stalls only
  for the copy, not the I/O — the standard overlap trick at scale;
* restart discovery: `latest_step()` scans for committed steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import numpy as np

from ..serialization import deserialize, serialize


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, *, compress: bool = True,
         shard_every: int = 64) -> str:
    """Synchronous save; returns the committed directory."""
    d = os.path.join(path, f"step_{step:08d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    files = []
    for i in range(0, len(host), shard_every):
        blob = serialize(host[i:i + shard_every], format="binary")
        if compress:
            blob = zlib.compress(blob, level=1)
        name = f"shard_{i // shard_every:05d}.bin"
        with open(os.path.join(tmp, name), "wb") as f:
            f.write(blob)
        files.append(name)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(host), "files": files,
                   "compress": compress, "shard_every": shard_every,
                   "treedef": str(treedef)}, f)
    open(os.path.join(tmp, "COMMITTED"), "w").close()
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)
    return d


def restore(path: str, step: int, like):
    """Restore into the structure of ``like`` (a pytree of arrays/avals)."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    host: list[np.ndarray] = []
    for name in man["files"]:
        with open(os.path.join(d, name), "rb") as f:
            blob = f.read()
        if man["compress"]:
            blob = zlib.decompress(blob)
        host.extend(deserialize(blob, format="binary"))
    _, treedef = _flatten(like)
    leaves_like = jax.tree.leaves(like)
    assert len(host) == len(leaves_like), (len(host), len(leaves_like))
    out = [np.asarray(h).astype(l.dtype).reshape(l.shape)
           for h, l in zip(host, leaves_like)]
    return jax.tree.unflatten(treedef, out)


def latest_step(path: str) -> int | None:
    """Restart discovery: newest committed step, or None."""
    if not os.path.isdir(path):
        return None
    best = None
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(path, name, "COMMITTED")):
                s = int(name.split("_")[1])
                best = s if best is None else max(best, s)
    return best


class AsyncCheckpointer:
    """Overlap checkpoint I/O with compute (device->host copy is sync)."""

    def __init__(self, path: str, *, keep: int = 3):
        self.path = path
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Future | None = None
        self._lock = threading.Lock()

    def save(self, step: int, tree) -> Future:
        self.wait()                                   # one in flight
        host = jax.tree.map(np.asarray, tree)         # snapshot now

        def _write():
            save(self.path, step, host)
            self._gc()

        with self._lock:
            self._pending = self._pool.submit(_write)
        return self._pending

    def wait(self):
        with self._lock:
            p = self._pending
        if p is not None:
            p.result()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.path)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def close(self):
        self.wait()
        self._pool.shutdown()
