"""Sharded AdamW with cosine schedule, global-norm clipping, gradient
accumulation, and an int8 error-feedback compressor for the DP all-reduce.

Functional, optax-shaped but self-contained (the container ships no optax):

  opt = AdamW(lr=..., ...)
  state = opt.init(params)            # moments inherit the param specs
  params, state, metrics = opt.update(grads, state, params)

Moments are fp32 regardless of param dtype (bf16 training-stable).  The
logical-spec tree for the optimizer state is the param spec tree — so TP/
FSDP sharding of the moments follows the params for free (ZeRO-style: the
fp32 moments are sharded at least as finely as the bf16 params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * step / max(1, warmup)
    t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), norm


@dataclass(frozen=True)
class AdamW:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(self, param_specs):
        """Logical specs for the optimizer state (moments mirror params)."""
        return {"mu": param_specs, "nu": param_specs, "step": ()}

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = cosine_schedule(step, peak_lr=self.peak_lr, warmup=self.warmup,
                             total=self.total_steps)
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        metrics = {"lr": lr, "grad_norm": gnorm}
        return new_params, {"mu": mu, "nu": nu, "step": step}, metrics


# ------------------------------------------------- gradient accumulation --

def accumulate_grads(loss_fn, params, microbatches, *args):
    """Mean-accumulate grads over leading-dim microbatches via lax.scan."""
    def one(carry, mb):
        acc, lsum = carry
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb, *args)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, lsum + l), aux

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc, lsum), auxs = jax.lax.scan(one, (zeros, jnp.float32(0)),
                                     microbatches)
    n = jax.tree.leaves(microbatches)[0].shape[0]
    grads = jax.tree.map(lambda g: g / n, acc)
    return grads, lsum / n, auxs


# --------------------------------------- int8 error-feedback compression --

def compress_int8(g, err):
    """Quantize g+err to int8 with a per-tensor scale; returns
    (q, scale, new_err).  Used to compress the DP all-reduce payload 4x
    (bf16->int8+scale); the residual carries to the next step."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, err_tree):
    out = jax.tree.map(compress_int8, grads, err_tree)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e
