from .adamw import (AdamW, accumulate_grads, clip_by_global_norm,
                    compress_int8, cosine_schedule, decompress_int8,
                    ef_compress_tree, global_norm)
