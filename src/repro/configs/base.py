"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any architecture in the assigned pool; family-
specific fields are zero/empty when unused.  ``ShapeConfig`` describes one
assigned input-shape cell.  Everything is frozen and hashable so configs can
key compilation caches and manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # "einsum" (GShard dense dispatch, oracle) | "ep" (shard_map all_to_all)
    impl: str = "einsum"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 0          # N (mamba2 ssm_state / rwkv head size)
    n_heads: int = 0            # SSD heads / wkv heads
    head_dim: int = 0           # P per head
    expand: int = 2             # mamba2 inner expansion
    chunk: int = 128            # SSD/WKV chunk length
    conv_width: int = 4         # mamba2 depthwise conv


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    act: str = "swiglu"         # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) freq split
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # pad token id for batched serving (runtime/server.pack_prompts).  Any
    # valid embedding index works — per-row lengths, not sentinel scanning,
    # are the source of truth for what is padding, and pad slots are masked
    # out of attention / recurrent state everywhere — but it must be a
    # legal row of the embedding table (0 <= pad_id < vocab_size).
    pad_id: int = 0
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    # hybrid (zamba2): one shared attention block applied every k SSM blocks
    shared_attn_every: int = 0
    shared_attn_lora_rank: int = 0
    # encdec (whisper)
    encoder_layers: int = 0
    decoder_layers: int = 0
    # attention window (0 = full causal). zamba2 shared attn & long-ctx decode
    window: int = 0
    # numerics / runtime
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    kv_quant: str = "none"      # none | int8 (per-token-per-head scales)
    logits_fp32: bool = True
    remat: str = "none"         # none | full | dots_saveable
    attn_impl: str = "auto"     # auto | pallas | ref
    scan_layers: bool = True
    # embeddings fed directly (vlm/audio frontends are stubs)
    embeds_input: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid / windowed)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6·N·D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        qo = self.n_heads * self.head_dim
        kv = self.n_kv_heads * self.head_dim
        attn = d * qo + 2 * d * kv + qo * d
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = glu * d * ff
        if self.moe.n_experts:
            mlp *= self.moe.n_experts
            mlp += d * self.moe.n_experts          # router
        norms = 2 * d
        if self.family == "ssm":                   # rwkv6 block
            att = self.ssm.n_heads * self.ssm.head_dim
            blk = (4 * d * att                     # r,k,v,g (w is low-rank)
                   + d * 64 + 64 * att             # w lora
                   + att * d                       # out
                   + 3.5 * d * ff / (ff / d) * 0)  # (ffn counted via mlp below)
            mlp = 2 * d * ff                       # rwkv channel-mix: k,v (r small)
            per_layer = blk + mlp + norms
            return int(self.n_layers * per_layer + 2 * v * d)
        if self.family == "hybrid":
            di = self.ssm.expand * d
            mamba = (2 * d * di + di * self.ssm.conv_width
                     + di * 2 * self.ssm.state_dim + di  # B,C,dt proj (grouped)
                     + di * d)
            n_shared = (self.n_layers // max(1, self.shared_attn_every))
            shared = attn + glu * d * ff
            lora = n_shared * 2 * self.shared_attn_lora_rank * d * 2
            return int(self.n_layers * (mamba + norms)
                       + shared + lora + 2 * v * d)
        layers = self.n_layers or (self.encoder_layers + self.decoder_layers)
        per_layer = attn + mlp + norms
        if self.family == "encdec":                # decoder cross-attn
            per_layer = attn + mlp + norms
            dec_extra = attn                        # cross attention block
            return int(self.encoder_layers * per_layer
                       + self.decoder_layers * (per_layer + dec_extra)
                       + v * d + 2 * d)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return int(layers * per_layer + emb + d)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.moe.n_experts:
            return self.param_count()
        full = self.param_count()
        d, ff = self.d_model, self.d_ff
        glu = 3 if self.act in ("swiglu", "geglu") else 2
        all_experts = self.n_layers * glu * d * ff * self.moe.n_experts
        active = self.n_layers * glu * d * ff * self.moe.top_k
        return int(full - all_experts + active)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------- wire ----
# Model configs ride in serve-task payloads (the generate closure's data
# capture, ISSUE 3): register them with the pytree reflection layer so the
# wire format can carry them — the cereal-style "user adds serialization
# for custom types" hook (paper §3.3).  Registration happens at import
# time on both sides (client deploy and worker thaw import this module).
from ..serialization.pytree import register_custom as _register_custom  # noqa: E402

for _cls in (MoEConfig, SSMConfig, ModelConfig, ShapeConfig):
    _register_custom(_cls)
