"""qwen1.5-4b — dense MHA with QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912, vocab_size=151936,
    act="swiglu", qkv_bias=True, rope_theta=1e6,
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, remat="none")
