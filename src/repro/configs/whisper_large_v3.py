"""whisper-large-v3 — enc-dec audio backbone; conv frontend is a stub
(input_specs feeds precomputed frame embeddings).  [arXiv:2212.04356;
unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, head_dim=64, d_ff=5120, vocab_size=51866,
    act="gelu", qkv_bias=True, rope_theta=0.0,
    encoder_layers=32, decoder_layers=32, embeds_input=True,
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="whisper-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, encoder_layers=2,
    decoder_layers=2, remat="none")
