"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", n_layers=24, d_model=2048,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=7168, vocab_size=65536,
    act="gelu", rope_theta=0.0,
    ssm=SSMConfig(state_dim=64, n_heads=32, head_dim=64, chunk=64),
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="rwkv6-smoke", n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    ssm=SSMConfig(state_dim=8, n_heads=4, head_dim=8, chunk=16),
    remat="none")
