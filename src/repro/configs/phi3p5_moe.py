"""phi3.5-moe-42b-a6.6b — 16 experts, top-2 routing.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=6400, vocab_size=32064,
    act="swiglu", rope_theta=10_000.0,
    moe=MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25),
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="phi3.5-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=1.25),
    remat="none")
