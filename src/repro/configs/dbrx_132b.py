"""dbrx-132b — 16 experts, top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=10752, vocab_size=100352,
    act="swiglu", rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, capacity_factor=1.25),
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256,
    moe=MoEConfig(n_experts=4, top_k=4, capacity_factor=1.25),
    remat="none")
