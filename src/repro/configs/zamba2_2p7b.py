"""zamba2-2.7b — hybrid Mamba2 + shared attention blocks w/ LoRA.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240, vocab_size=32000,
    act="geglu", rope_theta=10_000.0,
    ssm=SSMConfig(state_dim=64, n_heads=80, head_dim=64, expand=2,
                  chunk=128, conv_width=4),
    shared_attn_every=6, shared_attn_lora_rank=128,
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256,
    ssm=SSMConfig(state_dim=16, n_heads=16, head_dim=8, expand=2,
                  chunk=16, conv_width=4),
    shared_attn_every=2, shared_attn_lora_rank=8, remat="none")
