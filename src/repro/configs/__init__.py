"""Architecture registry: ``--arch <id>`` resolves here."""
from importlib import import_module

from .base import ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES

_MODULES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "zamba2-2.7b": "zamba2_2p7b",
    "phi3.5-moe-42b-a6.6b": "phi3p5_moe",
    "dbrx-132b": "dbrx_132b",
    "qwen2-7b": "qwen2_7b",
    "smollm-360m": "smollm_360m",
    "gemma-2b": "gemma_2b",
    "qwen1.5-4b": "qwen1p5_4b",
    "whisper-large-v3": "whisper_large_v3",
    "rwkv6-1.6b": "rwkv6_1p6b",
}
ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return import_module(f".{_MODULES[arch]}", __package__).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return import_module(f".{_MODULES[arch]}", __package__).SMOKE


def cells(arch: str) -> list[str]:
    """Shape cells assigned to this arch (long_500k only for sub-quadratic;
    skips are recorded in the roofline table, per DESIGN Arch-applicability)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out


__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
           "ARCHS", "get_config", "get_smoke", "cells"]
