"""gemma-2b — GeGLU, head_dim=256, MQA.  [arXiv:2403.08295; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab_size=256000,
    act="geglu", rope_theta=10_000.0, tie_embeddings=True,
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="gemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    head_dim=16, d_ff=128, vocab_size=256, remat="none")
