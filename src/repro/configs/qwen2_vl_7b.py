"""qwen2-vl-7b — VLM backbone: M-RoPE, dynamic resolution (frontend stub).
[arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944, vocab_size=152064,
    act="swiglu", qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24), embeds_input=True,
    remat="dots_saveable")

SMOKE = CONFIG.replace(
    name="qwen2-vl-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256, mrope_sections=(2, 3, 3),
    remat="none")
