"""Pluggable execution backends behind the dispatcher (API redesign, PR 1).

Cppless's promise is that *switching backends never touches application
code* (paper §4.1: one dispatcher type per cloud).  Here that boundary is an
explicit protocol: anything with ``submit / scale_to / drain_warm /
shutdown`` plus ``capabilities`` can stand in for the FaaS fleet, and a
string registry lets ``Dispatcher(backend="...")`` / ``cloud.Session("...")``
select one without importing it.

Built-in backends:

* ``"threads"``   — today's elastic ``WorkerPool`` (real OS threads, warm
                    sandbox reuse, fault injection).
* ``"inline"``    — synchronous, zero-thread execution on the caller's
                    thread; deterministic, ideal for tests and debugging.
* ``"sim-aws"``   — threads plus the calibrated ``LatencyModel`` composed in:
                    every record gets a modeled client-observed latency
                    (cold start + RTT + congestion), so cloud-shaped numbers
                    come out of ordinary runs.
* ``"processes"`` — real multiprocessing workers behind the wire protocol:
                    GIL-free execution, bridges rebuilt from the manifest on
                    first use, warm reuse across invocations.
* ``"http"``      — the paper's actual client model: payloads POSTed to a
                    separately-spawned ``http.server`` worker over pooled
                    keep-alive connections; records carry *measured*
                    client-observed latency (``latency_measured=True``).
* ``"http-aio"``  — the same worker model driven by one event loop and a
                    multiplexed asyncio client (conns × streams budget,
                    ISSUE 3): in-flight requests cost socket reads, not
                    blocked threads.  See ``repro.serving``.

Third-party backends register with ``register_backend("name")``.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol, runtime_checkable

from .futures import Invocation
from .latency_model import DEFAULT_LATENCY, LatencyModel
from .transports import HttpBackend, ProcessesBackend
from .workers import BackendCapabilities, FaultPlan, WorkerPool


@runtime_checkable
class Backend(Protocol):
    """The execution-backend contract the dispatcher programs against."""

    capabilities: BackendCapabilities

    def submit(self, inv: Invocation) -> None:
        """Accept one invocation; deliver completion via the future /
        ``inv.on_complete`` (may happen synchronously)."""

    def scale_to(self, os_threads: int) -> None:
        """Elastic scale-out of real executors (no-op where meaningless)."""

    def drain_warm(self, function_name: str | None = None) -> int:
        """Scale-in: drop warm sandboxes; returns how many were dropped."""

    def shutdown(self) -> None:
        """Stop accepting work and release executors."""


# ------------------------------------------------------------- registry ----

_REGISTRY: dict[str, Callable[..., Backend]] = {}


def register_backend(name: str, factory: Callable[..., Backend] | None = None):
    """Register a backend factory under ``name`` (usable as a decorator).

    Factories are called with the dispatcher's standard keyword set
    (``max_concurrency, os_threads, fault_plan, latency, client,
    deployment``) and must tolerate extras (accept ``**_``).
    """
    def _register(f):
        _REGISTRY[name] = f
        return f
    return _register(factory) if factory is not None else _register


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(spec: str | Backend | Callable[..., Backend],
                    **opts: Any) -> Backend:
    """Turn a backend spec into a live backend.

    ``spec`` may be a registry name, an already-constructed backend
    (returned as-is), or a factory callable.
    """
    if isinstance(spec, str):
        try:
            factory = _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown backend {spec!r}; available: "
                f"{', '.join(available_backends())}") from None
        return factory(**opts)
    if isinstance(spec, type):               # backend class → construct it
        return spec(**opts)
    if isinstance(spec, Backend):            # structural check: live backend
        return spec
    if callable(spec):
        return spec(**opts)
    raise TypeError(f"backend spec must be a name, Backend, or factory; "
                    f"got {type(spec).__name__}")


# ------------------------------------------------------------- builtins ----

class InlineBackend(WorkerPool):
    """Synchronous zero-thread backend: ``submit`` runs the task in place.

    Keeps the full sandbox simulation (cold/warm accounting, fault
    injection, retry/hedging policy via ``on_complete``) but with
    deterministic caller-thread execution — the debugger-friendly mode.
    """

    capabilities = BackendCapabilities(concurrent=False, warm_reuse=True,
                                       fault_injection=True,
                                       resident_state=True)

    def __init__(self, *, max_concurrency: int = 1000,
                 fault_plan: FaultPlan | None = None, **_):
        super().__init__(max_concurrency=max_concurrency, os_threads=0,
                         fault_plan=fault_plan)

    def submit(self, inv: Invocation) -> None:
        if inv.future.done():               # hedged sibling already won
            return
        try:
            self._execute(inv)              # retries recurse through submit
        except BaseException as e:          # executor bug must not propagate
            inv.future.set_error(e)

    def scale_to(self, os_threads: int) -> None:
        pass                                # there is nothing to scale


class SimAWSBackend(WorkerPool):
    """Threads backend with the cloud-client model composed in.

    Execution is real (inherited worker pool + ``FaultPlan``); on every
    completion the calibrated ``LatencyModel`` stamps the record with the
    client-observed latency an AWS deployment would see: per-invoke RTT +
    server time + cold-start penalty + congestion for the current in-flight
    load.  This is the backend benchmarks use to report cloud-shaped
    latencies from container runs.
    """

    capabilities = BackendCapabilities(concurrent=True, warm_reuse=True,
                                       fault_injection=True,
                                       models_latency=True)

    def __init__(self, *, max_concurrency: int = 1000, os_threads: int = 16,
                 fault_plan: FaultPlan | None = None,
                 latency: LatencyModel = DEFAULT_LATENCY,
                 client: str = "http2_pool", **_):
        super().__init__(max_concurrency=max_concurrency,
                         os_threads=os_threads, fault_plan=fault_plan)
        self.latency = latency
        self.client = client
        self._inflight = 0

    def submit(self, inv: Invocation) -> None:
        with self._lock:
            self._inflight += 1
        super().submit(inv)

    def _skipped(self, inv) -> None:
        with self._lock:
            self._inflight -= 1

    def _post_execute(self, inv, rec, ok: bool) -> None:
        with self._lock:
            inflight = self._inflight
            self._inflight -= 1
        m = self.latency
        rec.modeled_latency_ms = (
            m.per_invoke_overhead_ms(self.client)
            + rec.server_s * 1000.0
            + (m.cold_start_ms if rec.cold_start else 0.0)
            + m.congestion_ms_per_inflight
            * min(inflight, m.capacity(self.client)))


@register_backend("threads")
def _threads_backend(*, max_concurrency: int = 1000, os_threads: int = 16,
                     fault_plan: FaultPlan | None = None, **_) -> WorkerPool:
    return WorkerPool(max_concurrency=max_concurrency, os_threads=os_threads,
                      fault_plan=fault_plan)


register_backend("inline", InlineBackend)
register_backend("sim-aws", SimAWSBackend)
register_backend("processes", ProcessesBackend)
register_backend("http", HttpBackend)


@register_backend("http-aio")
def _http_aio_backend(**opts: Any) -> Backend:
    """The ``http`` worker model driven by one event loop — N in-flight
    requests cost N socket reads, not N blocked threads (ISSUE 3).  Lazy
    import: ``repro.serving`` sits above the dispatch layer."""
    from ..serving.http_client import AioHttpBackend
    return AioHttpBackend(**opts)

# the "threads" backend IS the worker pool — exported under both names
ThreadsBackend = WorkerPool
