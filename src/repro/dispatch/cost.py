"""Pay-as-you-go cost accounting (paper Fig 14).

AWS Lambda pricing model: GB-seconds (billed duration, 1 ms granularity,
× configured memory) plus a per-request charge.  The paper's Fig 14 metric is
total GB-seconds across all tasks; its claim is that cost stays ~flat as
parallelism grows because billing is proportional to productive work.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .futures import InvocationRecord

# us-east-1 x86 prices at time of paper
PRICE_PER_GB_S = 0.0000166667
PRICE_PER_REQUEST = 0.20 / 1_000_000
# paper §1 comparison point: a t3.small-ish VM with 2 vCPUs
VM_PRICE_PER_HOUR = 0.048


@dataclass
class CostReport:
    records: list[InvocationRecord] = field(default_factory=list)

    def add(self, rec: InvocationRecord) -> None:
        self.records.append(rec)

    @property
    def invocations(self) -> int:
        return len(self.records)

    @property
    def gb_seconds(self) -> float:
        return sum(r.billed_gb_s for r in self.records)

    @property
    def compute_seconds(self) -> float:
        return sum(r.server_s for r in self.records)

    @property
    def dollars(self) -> float:
        return (self.gb_seconds * PRICE_PER_GB_S
                + self.invocations * PRICE_PER_REQUEST)

    def vm_equivalent_hours(self) -> float:
        """How long the paper's benchmark VM could run for the same money."""
        return self.dollars / VM_PRICE_PER_HOUR

    def summary(self) -> dict:
        return {
            "invocations": self.invocations,
            "gb_seconds": round(self.gb_seconds, 6),
            "compute_seconds": round(self.compute_seconds, 6),
            "dollars": round(self.dollars, 8),
            "cold_starts": sum(1 for r in self.records if r.cold_start),
            "retries": sum(r.attempts - 1 for r in self.records),
            "hedged_wins": sum(1 for r in self.records if r.hedged),
        }
