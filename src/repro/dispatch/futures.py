"""Invocation futures, per-invocation records, and streaming fork-join.

``as_completed`` / ``gather`` are the composition primitives of the
session API (ISSUE 1): results stream in completion order instead of
blocking on submit order, and partial failure is a policy, not a crash.
"""
from __future__ import annotations

import queue
import threading
import time
from asyncio import CancelledError
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator, Sequence


@dataclass
class InvocationRecord:
    """Everything we know about one serverless invocation."""
    task_id: int
    function_name: str
    worker_id: int = -1
    cold_start: bool = False
    attempts: int = 1
    hedged: bool = False              # a backup request won the race
    # server-side (execution) accounting, seconds
    deserialize_s: float = 0.0
    compute_s: float = 0.0
    serialize_s: float = 0.0
    server_s: float = 0.0             # billable duration
    # client-observed latency (ms): filled by the sim-aws latency *model*,
    # or — on the http transport — by a real wall-clock *measurement*
    # (latency_measured=True distinguishes the two; same field so sim and
    # real numbers are directly comparable)
    modeled_latency_ms: float = 0.0
    latency_measured: bool = False
    payload_bytes: int = 0
    result_bytes: int = 0
    memory_gb: float = 1.0

    @property
    def billed_gb_s(self) -> float:
        """AWS Lambda bills ceil-to-1ms × configured memory."""
        import math
        billed_ms = max(1, math.ceil(self.server_s * 1000.0))
        return billed_ms / 1000.0 * self.memory_gb


class InvocationCancelled(CancelledError):
    """The client abandoned this invocation before it completed.

    A serverless task cannot be un-invoked once a worker picks it up, but a
    *queued* invocation whose future is cancelled is skipped by every
    backend (they check ``future.done()`` before executing).  Subclasses
    ``CancelledError`` so async callers see standard cancellation
    semantics; sync callers get it raised from ``result()``.
    """


class InvocationFuture:
    """Minimal future with completion callbacks (used for hedging races).

    ``add_done_callback`` is the async bridge contract (ISSUE 3): it is
    thread-safe, each registered callback fires *exactly once* — from the
    completing thread, or immediately from the registering thread when the
    future is already done — and the registry is dropped after completion
    so callbacks never pin payload-sized closures.
    """

    def __init__(self, task_id: int):
        self.task_id = task_id
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None
        self.record: InvocationRecord | None = None
        self._callbacks: list[Callable[["InvocationFuture"], None]] = []
        self._lock = threading.Lock()
        self._claimed = False

    def done(self) -> bool:
        return self._event.is_set()

    def claim(self) -> bool:
        """Atomically claim the right to complete this future.

        Exactly one completion (original, retry, or hedged backup) wins.
        The winner may then do pre-resolution bookkeeping (cost records)
        *before* calling ``set_result``/``set_error`` — guaranteeing the
        accounting is visible by the time ``result()`` waiters wake.
        """
        with self._lock:
            if self._claimed or self._event.is_set():
                return False
            self._claimed = True
            return True

    def set_result(self, value: Any, record: InvocationRecord) -> bool:
        """Returns True iff this call won the write race (hedging: first
        writer wins) — the atomic signal completion policy keys off."""
        with self._lock:
            if self._event.is_set():
                return False
            self._result = value
            self.record = record
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        self._run_callbacks(callbacks)
        return True

    def set_error(self, err: BaseException,
                  record: InvocationRecord | None = None) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._error = err
            self.record = record
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        self._run_callbacks(callbacks)
        return True

    def cancel(self, reason: str | None = None) -> bool:
        """Abandon the invocation: complete the future with
        :class:`InvocationCancelled`.  Returns ``True`` iff this call won —
        a completion already claimed (a worker is delivering its result
        right now) or already done cannot be cancelled.  Backends skip
        queued invocations whose future is done, so cancelling before a
        worker picks the task up really does shed the work."""
        if not self.claim():
            return False
        return self.set_error(InvocationCancelled(
            reason or f"invocation {self.task_id} cancelled"))

    def cancelled(self) -> bool:
        return self._event.is_set() and \
            isinstance(self._error, InvocationCancelled)

    def _run_callbacks(self, callbacks) -> None:
        for cb in callbacks:
            try:
                cb(self)
            except Exception:
                # a user callback bug must not corrupt the completion flow
                # (double finish, negative in-flight counts, hung wait())
                pass

    def add_done_callback(self, cb: Callable[["InvocationFuture"], None]) -> None:
        """Thread-safe; ``cb(self)`` fires exactly once — immediately (on
        the calling thread) if the future is already done, else on the
        thread that completes it."""
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(cb)
        if run_now:
            self._run_callbacks([cb])

    def result(self, timeout: float | None = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(f"invocation {self.task_id} timed out")
        if self._error is not None:
            raise self._error
        return self._result

    def exception(self, timeout: float | None = None) -> BaseException | None:
        """The settled error (or ``None`` on success) without raising it —
        the non-throwing peek completion callbacks use."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"invocation {self.task_id} timed out")
        return self._error


def as_completed(futs: Iterable[InvocationFuture],
                 timeout: float | None = None) -> Iterator[InvocationFuture]:
    """Yield futures as they complete, earliest-done first.

    The streaming half of fork-join: consumers overlap reduction with the
    remaining remote work instead of blocking on submit order.  ``timeout``
    bounds the *total* wait for the whole set.
    """
    futs = list(futs)
    done: "queue.Queue[InvocationFuture]" = queue.Queue()
    for f in futs:
        f.add_done_callback(done.put)       # fires immediately if already done
    deadline = None if timeout is None else time.monotonic() + timeout
    for _ in range(len(futs)):
        remaining = None
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError("as_completed() timed out")
        try:
            yield done.get(timeout=remaining)
        except queue.Empty:
            raise TimeoutError("as_completed() timed out") from None


def gather(futs: Sequence[InvocationFuture], *,
           return_exceptions: bool = False,
           timeout: float | None = None) -> list[Any]:
    """Resolve a batch of futures, in submit order.

    Partial-failure policy: by default the first failed invocation raises
    (after letting in-flight siblings run on); with
    ``return_exceptions=True`` the exception object takes the failed slot —
    the caller decides what a partial fan-out is worth.  ``timeout`` bounds
    the total wait across the batch and always raises ``TimeoutError`` when
    exceeded — an unfinished task is not a settled failure, so the batch
    deadline is never folded into the partial-failure policy.
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    out: list[Any] = []
    first_error: Exception | None = None
    for f in futs:
        remaining = None
        if deadline is not None:
            remaining = max(0.0, deadline - time.monotonic())
        try:
            out.append(f.result(timeout=remaining))
        except (Exception, CancelledError) as e:
            # KeyboardInterrupt etc. must propagate; InvocationCancelled
            # (a CancelledError) is a *settled* per-task outcome and takes
            # part in the partial-failure policy like any task error
            if isinstance(e, TimeoutError) and not f.done():
                raise               # batch deadline hit: task still in flight
            if return_exceptions:
                out.append(e)
            elif first_error is None:
                first_error = e     # keep draining so siblings settle
    if first_error is not None:
        raise first_error
    return out


@dataclass
class Invocation:
    """A unit of dispatch: payload + routing metadata."""
    task_id: int
    deployed: Any                      # core.deploy.DeployedFunction
    payload: bytes
    future: InvocationFuture
    attempt: int = 1
    is_hedge: bool = False
    submit_order: int = 0
    tags: dict = field(default_factory=dict)
    # per-call policy config (timeout/retries/hedging); falls back to the
    # deployed function's config when None.  Policy travels with the
    # invocation so overriding it never forces a redeploy.
    config: Any = None                 # core.config.FunctionConfig
    # set by the dispatcher: (inv, ok, value_or_error, record) -> None.
    # Lets retry/hedging policy live in the dispatcher, not the pool.
    on_complete: Callable[["Invocation", bool, Any, InvocationRecord], None] | None = None
    # obs.trace.SpanContext of the root client.submit span, when this
    # request was sampled; transports parent their spans under it and put
    # its wire form on the INVOKE envelope.
    trace: Any = None
    # absolute epoch-seconds deadline stamped at dispatch from
    # ``config.deadline_s``; rides the wire (workers reject expired work)
    # and gates the retry path (never resubmit past it).  None = no limit.
    deadline: float | None = None
