"""Invocation futures and per-invocation records."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class InvocationRecord:
    """Everything we know about one serverless invocation."""
    task_id: int
    function_name: str
    worker_id: int = -1
    cold_start: bool = False
    attempts: int = 1
    hedged: bool = False              # a backup request won the race
    # server-side (execution) accounting, seconds
    deserialize_s: float = 0.0
    compute_s: float = 0.0
    serialize_s: float = 0.0
    server_s: float = 0.0             # billable duration
    # modeled client-observed latency (ms), from the latency model
    modeled_latency_ms: float = 0.0
    payload_bytes: int = 0
    result_bytes: int = 0
    memory_gb: float = 1.0

    @property
    def billed_gb_s(self) -> float:
        """AWS Lambda bills ceil-to-1ms × configured memory."""
        import math
        billed_ms = max(1, math.ceil(self.server_s * 1000.0))
        return billed_ms / 1000.0 * self.memory_gb


class InvocationFuture:
    """Minimal future with completion callbacks (used for hedging races)."""

    def __init__(self, task_id: int):
        self.task_id = task_id
        self._event = threading.Event()
        self._result: Any = None
        self._error: BaseException | None = None
        self.record: InvocationRecord | None = None
        self._callbacks: list[Callable[["InvocationFuture"], None]] = []
        self._lock = threading.Lock()

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, value: Any, record: InvocationRecord) -> None:
        with self._lock:
            if self._event.is_set():
                return                      # hedging: first writer wins
            self._result = value
            self.record = record
            self._event.set()
            callbacks = list(self._callbacks)
        for cb in callbacks:
            cb(self)

    def set_error(self, err: BaseException,
                  record: InvocationRecord | None = None) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._error = err
            self.record = record
            self._event.set()
            callbacks = list(self._callbacks)
        for cb in callbacks:
            cb(self)

    def add_done_callback(self, cb: Callable[["InvocationFuture"], None]) -> None:
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(cb)
        if run_now:
            cb(self)

    def result(self, timeout: float | None = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError(f"invocation {self.task_id} timed out")
        if self._error is not None:
            raise self._error
        return self._result


@dataclass
class Invocation:
    """A unit of dispatch: payload + routing metadata."""
    task_id: int
    deployed: Any                      # core.deploy.DeployedFunction
    payload: bytes
    future: InvocationFuture
    attempt: int = 1
    is_hedge: bool = False
    submit_order: int = 0
    tags: dict = field(default_factory=dict)
    # set by the dispatcher: (inv, ok, value_or_error, record) -> None.
    # Lets retry/hedging policy live in the dispatcher, not the pool.
    on_complete: Callable[["Invocation", bool, Any, InvocationRecord], None] | None = None
