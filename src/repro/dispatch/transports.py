"""Out-of-process transports: real ``processes`` and ``http`` backends.

PR 1 made backends pluggable but every one of them executed in the caller's
process — simulation.  These two ship the payload bytes across a real
boundary to a :class:`~repro.runtime.worker_host.WorkerHost` speaking the
versioned wire protocol:

* ``ProcessesBackend`` — one worker subprocess per slot (the worker-host
  CLI in ``--stdio`` mode), framed envelopes over stdin/stdout.  GIL-free:
  compute runs in the children; client threads only block on IO.  Workers
  rebuild bridges from the manifest on first use (a real cold start, AOT
  compile included) and reuse them warm across invocations.
* ``HttpBackend`` — the paper's actual client model: a separately-spawned
  ``http.server`` worker process plus a pool of persistent (keep-alive)
  HTTP/1.1 connections.  Every record's ``modeled_latency_ms`` is the
  *measured* client-observed roundtrip, flagged ``latency_measured`` —
  the field stops being a model and becomes a measurement.

Failure contract (the dead-worker satellite): a worker that dies
mid-request surfaces as a retryable ``WorkerCrash`` carrying whatever
traceback text the worker managed to send (EOF/connection loss synthesizes
one), the worker slot is respawned, and the dispatcher's ordinary retry
policy takes it from there — never a hung future.
"""
from __future__ import annotations

import http.client
import os
import struct
import subprocess
import sys
import tempfile
import threading
import time
import queue as queue_mod
from typing import Any

from ..core.deploy import Deployment
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..runtime.sandbox import ChaosPlan, WorkerCrash
from ..serialization import wire
from .futures import Invocation, InvocationRecord
from .workers import BackendCapabilities, fill_record

# client-side transport metrics (process-default registry; the worker-side
# twins ride back through host_stats and merge in ``stats()``)
_M_REQS = obs_metrics.REGISTRY.counter(
    "client_requests_total", "invocations sent over a real transport")
_M_CRASH = obs_metrics.REGISTRY.counter(
    "client_worker_crashes_total", "transport-level worker losses")
_M_RTT = obs_metrics.REGISTRY.histogram(
    "client_roundtrip_ms", "measured client-observed round-trip (ms)")
_M_QDEPTH = obs_metrics.REGISTRY.gauge(
    "client_queue_depth", "invocations waiting for a dispatch thread")
_M_CHAOS = obs_metrics.REGISTRY.counter(
    "chaos_injections_total", "chaos events executed against real workers")
_M_RESPAWN = obs_metrics.REGISTRY.counter(
    "client_worker_respawns_total",
    "worker slots respawned after a transport-level loss")


def _deliver(inv: Invocation, ok: bool, value: Any,
             rec: InvocationRecord) -> None:
    if inv.on_complete is not None:
        inv.on_complete(inv, ok, value, rec)
    elif ok:
        inv.future.set_result(value, rec)
    else:
        inv.future.set_error(value, rec)


def _worker_crash(message: str, traceback_text: str = "") -> WorkerCrash:
    e = WorkerCrash(message)
    e.remote_traceback = traceback_text        # type: ignore[attr-defined]
    return e


class _TransportBackend:
    """Shared client half: manifest persistence, dispatch threads, reply
    handling, measured-latency stamping.  Subclasses own the byte transport
    (``_request``) and worker lifecycle (``_spawn_slot`` / ``_close_slot``)."""

    capabilities = BackendCapabilities(concurrent=True, warm_reuse=True,
                                       measures_latency=True,
                                       cross_process=True,
                                       resident_state=True)

    def __init__(self, *, deployment: Deployment | None = None,
                 manifest_path: str | None = None, n_workers: int = 2,
                 chaos: ChaosPlan | None = None):
        if deployment is not None:
            self._manifest_path = self._persist_manifest(deployment)
        elif manifest_path is not None:
            self._manifest_path = manifest_path
            self._owns_manifest = False
        else:
            raise ValueError(
                f"{type(self).__name__} needs the client deployment (or an "
                "explicit manifest_path): workers rebuild bridges from the "
                "manifest")
        self._queue: "queue_mod.Queue[Invocation | None]" = queue_mod.Queue()
        self._threads: list[threading.Thread] = []
        self._slots: dict[int, Any] = {}
        self._lock = threading.Lock()
        self._started = False
        self._stop = False
        self._n_workers = max(1, n_workers)
        # affinity pinning (ISSUE 5): an affinity key maps to one slot
        # index, frozen at first use (scale_to growing n_workers must not
        # re-home resident state), served by a dedicated dispatch thread
        # per pinned slot.  Pinned and anonymous traffic may share a slot
        # — the per-slot lock already serializes the byte transport.
        self._affinity_slots: dict[int, int] = {}
        self._affinity_queues: dict[int, "queue_mod.Queue"] = {}
        self._affinity_threads: list[threading.Thread] = []
        # chaos injection (ISSUE 10): the seeded plan this client executes
        # for real — kill/stall/drop/expire against live worker slots.
        # ``_burned`` remembers slots discarded after a transport loss so
        # the lazy respawn in ``_slot_for`` is observable as an event.
        self.chaos = chaos
        self._burned: set[int] = set()
        self._respawn_count = 0

    def _persist_manifest(self, deployment: Deployment) -> str:
        """Workers share the client's manifest through the filesystem —
        ``Manifest.add`` re-saves on every deploy, workers reload on miss."""
        m = deployment.manifest
        if m.path is None:
            fd, path = tempfile.mkstemp(prefix="repro-manifest-",
                                        suffix=".json")
            os.close(fd)
            m.path = path
            self._owns_manifest = True
        else:
            self._owns_manifest = False
        m.save(m.path)
        return m.path

    # ------------------------------------------------------------ backend
    def submit(self, inv: Invocation) -> None:
        self._ensure_started()
        cfg = inv.config or inv.deployed.config
        affinity = getattr(cfg, "affinity", None)
        if affinity is None:
            self._queue.put(inv)
        else:
            self._affinity_queue(affinity).put(inv)

    def _affinity_slot(self, affinity: int) -> int:
        with self._lock:
            idx = self._affinity_slots.get(affinity)
            if idx is None:
                idx = affinity % self._n_workers
                self._affinity_slots[affinity] = idx
            return idx

    def _affinity_queue(self, affinity: int) -> "queue_mod.Queue":
        idx = self._affinity_slot(affinity)
        with self._lock:
            q = self._affinity_queues.get(idx)
            if q is None:
                q = queue_mod.Queue()
                self._affinity_queues[idx] = q
                t = threading.Thread(target=self._serve_queue,
                                     args=(idx, q), daemon=True)
                t.start()
                self._affinity_threads.append(t)
            return q

    def state_control(self, affinity: int, op: str, body: bytes = b"",
                      **data: Any) -> dict:
        """One CONTROL round-trip to the worker an affinity key pins —
        the client surface for state-lease management (ISSUE 5) and arena
        row migration (ISSUE 6).  A reply that carries a body (row
        extraction) surfaces it under the ``"_body"`` key.

        Transport-level connection loss here is normalized into a retryable
        :class:`WorkerCrash` with the usual exit-code/stderr-tail context
        (the dead-``url=``-worker satellite) — a raw ``ConnectionError``
        or socket error must never leak past the transport, so spawned and
        external workers share ONE recovery path."""
        idx = self._affinity_slot(affinity)
        slot = self._slot_for(idx)
        try:
            raw = self._request(slot, wire.encode_control(op, body=body,
                                                          **data))
        except Exception as e:
            detail = self._discard_slot(idx, e)
            _M_CRASH.inc(backend=type(self).__name__)
            raise _worker_crash(
                f"worker {idx} connection lost during control {op!r}: "
                f"{detail}") from e
        reply = wire.decode(raw)
        if isinstance(reply, wire.ErrorReply):
            raise wire.to_exception(reply)
        if not isinstance(reply, wire.ControlRequest):
            raise wire.WireProtocolError(
                f"unexpected control reply {type(reply).__name__}")
        out = dict(reply.data)
        if reply.body:
            out["_body"] = reply.body
        return out

    def _slot_control(self, slot, op: str, **data: Any) -> dict:
        """Best-effort CONTROL round-trip to one spawned slot (stats and
        scale-in probes; a dead worker just reports nothing).  Connection
        loss normalizes to :class:`WorkerCrash` like every other transport
        failure — callers catching ``Exception`` see no behavior change,
        callers that re-raise surface a retryable crash, not a socket
        error."""
        try:
            raw = self._request(slot, wire.encode_control(op, **data))
        except Exception as e:
            detail = self._slot_epitaph(slot) or (
                type(e).__name__ if not str(e) else str(e))
            raise _worker_crash(
                f"worker connection lost during control {op!r}: "
                f"{detail}") from e
        msg = wire.decode(raw)
        if isinstance(msg, wire.ErrorReply):
            raise wire.to_exception(msg)
        if not isinstance(msg, wire.ControlRequest):
            raise wire.WireProtocolError(
                f"unexpected control reply {type(msg).__name__}")
        return msg.data

    @property
    def queue_depth(self) -> int:
        return self._queue.qsize() + sum(
            q.qsize() for q in self._affinity_queues.values())

    def stats(self) -> dict:
        """Fleet observability: per-worker sandbox/state accounting, one
        ``host_stats`` CONTROL round-trip per *spawned* slot (an unspawned
        slot has no process, hence nothing resident)."""
        with self._lock:
            slots = dict(self._slots)
            n = self._n_workers
            pinned = dict(self._affinity_slots)
        workers: dict[int, dict] = {}
        totals = {"cold_starts": 0, "warm_hits": 0, "busy_s": 0.0,
                  "state_handles": 0}
        _M_QDEPTH.set(self.queue_depth)
        merged = obs_metrics.Registry()
        merged.merge(obs_metrics.REGISTRY.snapshot())
        for idx, slot in sorted(slots.items()):
            if slot is None:
                continue
            try:
                d = self._slot_control(slot, "host_stats")
            except Exception as e:
                workers[idx] = {"error": str(e) or type(e).__name__}
                continue
            workers[idx] = d
            merged.merge(d.get("metrics"))
            sb = d.get("sandboxes", {})
            totals["cold_starts"] += int(sb.get("cold_starts", 0))
            totals["warm_hits"] += int(sb.get("warm_hits", 0))
            totals["busy_s"] += float(sb.get("busy_s", 0.0))
            totals["state_handles"] += int(d.get("state", {}).get("count", 0))
        return {"n_workers": n, "spawned": len(workers),
                "respawns": self._respawn_count,
                "affinity_slots": pinned, "workers": workers,
                "metrics": merged.snapshot(), **totals}

    def scale_to(self, os_threads: int) -> None:
        n = max(1, int(os_threads))
        with self._lock:
            cur = self._n_workers
        if n >= cur:
            with self._lock:
                self._n_workers = max(self._n_workers, n)
            if self._started:
                self._ensure_started(force_resize=True)
            return
        # ---- scale-in (ISSUE 6): slots above the new fleet size may hold
        # affinity-pinned resident state.  Re-homing a frozen affinity
        # would hand its next invocation a blank arena mid-serve, so this
        # REFUSES while any doomed slot holds a live state lease — callers
        # drain the fleet member (or release the lease) first.
        with self._lock:
            doomed = {aff: idx for aff, idx in self._affinity_slots.items()
                      if idx >= n}
            doomed_slots = sorted(set(doomed.values()))
            slot_objs = {idx: self._slots.get(idx) for idx in doomed_slots}
        stranded = []
        for idx in doomed_slots:
            slot = slot_objs.get(idx)
            if slot is None:
                continue               # never spawned: nothing resident
            try:
                st = self._slot_control(slot, "state_stats")
            except Exception:
                continue               # dead worker holds nothing
            if int(st.get("count", 0)):
                stranded.append((idx, list(st.get("handles", []))))
        if stranded:
            detail = "; ".join(
                f"worker {idx} holds {', '.join(h[:12] for h in hs)}"
                for idx, hs in stranded)
            raise RuntimeError(
                f"scale_to({n}) would strand live state leases on pinned "
                f"workers ({detail}): drain those engines or release their "
                "handles first — refusing to silently re-home resident "
                "arenas")
        closing = []
        with self._lock:
            self._n_workers = n
            for aff in list(doomed):
                # safe to re-home: the pin re-freezes at aff % n next use
                self._affinity_slots.pop(aff, None)
            for idx in [i for i in list(self._slots) if i >= n]:
                slot = self._slots.pop(idx)
                if slot is not None:
                    closing.append(slot)
            for idx in [i for i in list(self._affinity_queues) if i >= n]:
                self._affinity_queues.pop(idx).put(None)   # retire its thread
        for slot in closing:
            try:
                self._close_slot(slot)
            except Exception:
                pass

    def drain_warm(self, function_name: str | None = None) -> int:
        """Drop warm sandboxes in every live worker (control roundtrip);
        ``function_name`` (the mangled bridge name) scopes the drain, as on
        the in-process pool."""
        total = 0
        with self._lock:
            slots = list(self._slots.items())
        frame = wire.encode_control("drain", function=function_name)
        for idx, slot in slots:
            if slot is None:
                continue
            try:
                msg = wire.decode(self._request(slot, frame))
                if isinstance(msg, wire.ControlRequest):
                    total += int(msg.data.get("count", 0))
            except Exception:
                pass                       # a dead worker has nothing warm
        return total

    def shutdown(self) -> None:
        self._stop = True
        for _ in self._threads:
            self._queue.put(None)
        with self._lock:
            aqueues = list(self._affinity_queues.values())
        for q in aqueues:
            q.put(None)
        with self._lock:
            slots, self._slots = dict(self._slots), {}
        for slot in slots.values():
            if slot is not None:
                try:
                    self._close_slot(slot)
                except Exception:
                    pass
        if getattr(self, "_owns_manifest", False):
            try:
                os.unlink(self._manifest_path)
            except OSError:
                pass

    # ----------------------------------------------------------- dispatch
    def _ensure_started(self, force_resize: bool = False) -> None:
        with self._lock:
            if self._started and not force_resize:
                return
            self._started = True
            while len(self._threads) < self._n_workers:
                idx = len(self._threads)
                t = threading.Thread(target=self._serve, args=(idx,),
                                     daemon=True)
                t.start()
                self._threads.append(t)

    def _slot_for(self, idx: int):
        with self._lock:
            slot = self._slots.get(idx)
        if slot is None:
            slot = self._spawn_slot(idx)
            with self._lock:
                self._slots[idx] = slot
                respawn = idx in self._burned
                self._burned.discard(idx)
                if respawn:
                    self._respawn_count += 1
            if respawn:
                # a slot burned by a crash (or a chaos kill) coming back:
                # worker death was added latency, and here is the receipt
                _M_RESPAWN.inc(backend=type(self).__name__)
                if self.chaos is not None:
                    self.chaos.record("worker.respawned", slot=idx)
        return slot

    def _serve(self, idx: int) -> None:
        self._serve_queue(idx, self._queue)

    def _serve_queue(self, idx: int,
                     queue: "queue_mod.Queue[Invocation | None]") -> None:
        while not self._stop:
            inv = queue.get()
            if inv is None:
                return
            if inv.future.done():          # hedged sibling already won
                continue
            try:
                self._execute(idx, inv)
            except BaseException as e:     # transport bug must not hang futures
                inv.future.set_error(e)

    def _execute(self, idx: int, inv: Invocation) -> None:
        # anonymous dispatch threads above a scaled-in fleet size share the
        # low slots instead of resurrecting retired workers (pinned traffic
        # re-froze its mapping below n in scale_to)
        idx %= max(1, self._n_workers)
        bridge = inv.deployed.bridge
        rec = InvocationRecord(
            task_id=inv.task_id, function_name=bridge.name,
            attempts=inv.attempt, hedged=inv.is_hedge,
            payload_bytes=len(inv.payload),
            memory_gb=bridge.config.memory_gb)
        label = type(self).__name__
        _M_REQS.inc(backend=label)
        ctx = inv.trace
        request = wire.encode_invoke(
            bridge.name, inv.payload, task_id=inv.task_id,
            attempt=inv.attempt,
            trace=ctx.to_wire() if ctx is not None else None,
            deadline=inv.deadline)
        tracer = obs_trace.TRACER
        if ctx is not None and ctx.t_start:
            # queue wait = context mint (dispatch) → this thread picking
            # the invocation up; derived, not measured, so it costs nothing
            # on the submit path
            tracer.span_at("client.queue", ctx, ctx.t_start,
                           max(0.0, time.time() - ctx.t_start), slot=idx)
        tspan = (tracer.span("client.transport", ctx, slot=idx,
                             backend=label)
                 if ctx is not None else obs_trace.NOOP)
        try:
            slot = self._slot_for(idx)
            if self.chaos is not None:
                self._inject_chaos(idx, slot)
            t0 = time.perf_counter()
            reply = self._request(slot, request)
            reply = self._serve_missing_artifacts(slot, request, reply)
            measured_ms = (time.perf_counter() - t0) * 1000.0
        except Exception as e:
            # transport loss: burn the slot, surface a retryable crash
            detail = self._discard_slot(idx, e)
            _M_CRASH.inc(backend=label)
            tspan.set("error.type", type(e).__name__)
            tspan.set("error.detail", detail[:2000])
            tspan.finish("error")
            _deliver(inv, False,
                     _worker_crash(f"worker {idx} died mid-request "
                                   f"(task {inv.task_id}): {detail}"), rec)
            return
        rec.modeled_latency_ms = measured_ms
        rec.latency_measured = True
        _M_RTT.observe(measured_ms, backend=label)
        tspan.set("bytes_out", len(request))
        tspan.set("bytes_in", len(reply))
        tspan.finish()
        self._complete(inv, reply, rec)

    def _serve_missing_artifacts(self, slot, request: bytes,
                                 reply: bytes) -> bytes:
        """Artifact remote fetch (ROADMAP): a worker that cannot resolve an
        ``ArtifactRef`` (no shared filesystem) answers ``ArtifactMissing``;
        the client pushes the blob over the wire (CONTROL ``artifact_put``)
        and replays the invocation.  Bounded by distinct shas, so a worker
        that keeps losing blobs cannot loop the client forever."""
        from ..serialization.artifacts import export_artifact_blob
        served: set[str] = set()
        while True:
            miss = wire.decode_artifact_missing(reply)
            if miss is None:
                return reply
            sha, path = miss
            if sha in served:
                return reply               # pushed already and still missing
            blob = export_artifact_blob(sha, path)
            if blob is None:
                return reply               # client doesn't have it either
            ack = wire.decode(self._request(
                slot, wire.encode_control("artifact_put", body=blob,
                                          sha=sha)))
            if not (isinstance(ack, wire.ControlRequest)
                    and ack.data.get("ok")):
                return reply
            served.add(sha)
            reply = self._request(slot, request)

    def _complete(self, inv: Invocation, reply: bytes,
                  rec: InvocationRecord) -> None:
        bridge = inv.deployed.bridge
        try:
            msg = wire.decode(reply)
        except wire.WireProtocolError as e:
            _deliver(inv, False,
                     _worker_crash(f"undecodable worker reply: {e}"), rec)
            return
        # worker-side spans ride the reply envelope (RESULT and ERROR both):
        # adopt them into the client collector so the tree stitches
        spans = getattr(msg, "spans", None)
        if spans:
            obs_trace.TRACER.ingest(spans)
        if isinstance(msg, wire.ErrorReply):
            if msg.retryable:
                _deliver(inv, False, _worker_crash(
                    f"{msg.etype}: {msg.message}", msg.traceback), rec)
            else:
                exc = wire.to_exception(msg)
                # user-code failure: append the deploy-time shippability
                # diagnostic that predicts it (NameError under the fresh-
                # globals contract, unserializable capture, ...) as a
                # "likely cause" hint on the remote traceback / span attrs
                try:
                    from ..analysis import attach_failure_hint
                    attach_failure_hint(exc, inv.deployed)
                except Exception:
                    pass
                _deliver(inv, False, exc, rec)
            return
        if not isinstance(msg, wire.ResultReply):
            _deliver(inv, False, _worker_crash(
                f"unexpected reply frame {type(msg).__name__}"), rec)
            return
        try:
            value = bridge.unpack_result(msg.blob)
        except Exception as e:
            _deliver(inv, False, wire.WireProtocolError(
                f"result blob deserialization failed: {e}"), rec)
            return
        fill_record(rec, stats=msg.stats, server_s=msg.server_s,
                    worker_id=msg.worker_id, cold_start=msg.cold_start,
                    result_bytes=len(msg.blob))
        _deliver(inv, True, value, rec)

    def _inject_chaos(self, idx: int, slot) -> None:
        """Execute the chaos events due on this slot's Nth invocation.

        ``kill`` and ``drop`` make THIS invocation fail (the kill lands
        before the request bytes go out, so the in-flight decode dies with
        the worker — the WorkerCrash/EOF path, then lazy respawn);
        ``stall`` wedges the dispatch thread (a client-side straggle long
        enough to threaten a state lease — what the heartbeat defends
        against); ``expire`` force-expires the worker's leases via the
        CONTROL ``chaos`` verb, then lets the invocation proceed into the
        state-lost KeyError."""
        for ev in self.chaos.on_invoke(idx):
            _M_CHAOS.inc(kind=ev.kind, backend=type(self).__name__)
            if ev.kind == "kill":
                self.chaos.record("worker.killed", slot=idx)
                self._chaos_kill(idx, slot)
            elif ev.kind == "drop":
                self.chaos.record("conn.dropped", slot=idx)
                raise ConnectionError(
                    f"chaos: connection to worker {idx} dropped")
            elif ev.kind == "stall":
                self.chaos.record("entry.stalled", slot=idx,
                                  stall_s=ev.stall_s)
                time.sleep(ev.stall_s)
            elif ev.kind == "expire":
                try:
                    out = self._slot_control(slot, "chaos",
                                             action="expire_leases")
                    self.chaos.record("lease.expired", slot=idx,
                                      handles=out.get("expired", []))
                except Exception:
                    self.chaos.record("lease.expired", slot=idx, handles=[])

    def _chaos_kill(self, idx: int, slot) -> None:
        """Hard-kill the slot's worker.  Default: the CONTROL ``die`` verb
        (``os._exit``, no reply) — the only lever for workers we did not
        spawn; subclasses with a subprocess handle SIGKILL it directly."""
        try:
            self._request(slot, wire.encode_control("chaos", action="die"))
        except Exception:
            pass                   # death mid-reply is the expected outcome

    def _discard_slot(self, idx: int, err: Exception) -> str:
        with self._lock:
            slot = self._slots.pop(idx, None)
            self._burned.add(idx)
        detail = type(err).__name__ if not str(err) else str(err)
        if slot is not None:
            try:
                detail = self._slot_epitaph(slot) or detail
            finally:
                try:
                    self._close_slot(slot)
                except Exception:
                    pass
        return detail

    # -- subclass surface ----------------------------------------------------
    def _spawn_slot(self, idx: int):
        raise NotImplementedError

    def _request(self, slot, data: bytes) -> bytes:
        raise NotImplementedError

    def _close_slot(self, slot) -> None:
        raise NotImplementedError

    def _slot_epitaph(self, slot) -> str | None:
        """Best-effort post-mortem (exit code, stderr tail) for crash messages."""
        return None


# ---------------------------------------------------------------- processes

def _worker_env() -> dict[str, str]:
    """Child env: the client's import path on PYTHONPATH (the worker must
    resolve the same package tree the client deployed from — the analogue
    of building the worker image alongside the client binary), everything
    else inherited (JAX_PLATFORMS etc. must match the client's)."""
    import repro
    # repro may be a namespace package (no __init__.py): use __path__
    pkg_dir = os.path.abspath(list(repro.__path__)[0])
    src_dir = os.path.dirname(pkg_dir)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src_dir, *(p for p in sys.path if p)])
    return env


class _ProcSlot:
    def __init__(self, proc: subprocess.Popen, stderr_path: str):
        self.proc = proc
        self.stderr_path = stderr_path
        self.lock = threading.Lock()       # drain vs dispatch interleaving


class ProcessesBackend(_TransportBackend):
    """Worker-subprocess fleet — GIL-free python tasks, warm reuse.

    Each slot is one worker-host CLI child in ``--stdio`` mode; requests
    are ``u32 length``-prefixed wire frames.  A separate OS process per
    sandbox means the payload genuinely crosses a process boundary — the
    worker shares nothing with the client but the manifest file.

    Fleet size defaults to ``min(os_threads, cpu_count)`` — more python
    workers than cores cannot add parallelism — and ``n_workers=`` takes
    it anywhere.  Slots spawn lazily, one per concurrently-busy dispatch
    thread, so an idle session never pays for a full fleet.
    """

    def __init__(self, *, deployment: Deployment | None = None,
                 manifest_path: str | None = None, os_threads: int = 16,
                 n_workers: int | None = None,
                 chaos: ChaosPlan | None = None, **_):
        if n_workers is None:
            n_workers = max(1, min(os_threads, os.cpu_count() or 1))
        super().__init__(deployment=deployment, manifest_path=manifest_path,
                         n_workers=n_workers, chaos=chaos)

    def _spawn_slot(self, idx: int) -> _ProcSlot:
        fd, stderr_path = tempfile.mkstemp(prefix="repro-worker-",
                                           suffix=".log")
        proc = subprocess.Popen(
            [sys.executable, "-u", "-m", "repro.runtime.worker_host",
             "--manifest", self._manifest_path, "--stdio",
             "--worker-id-base", str((idx + 1) * 1_000_000)],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, stderr=fd,
            env=_worker_env())
        os.close(fd)
        return _ProcSlot(proc, stderr_path)

    def _request(self, slot: _ProcSlot, data: bytes) -> bytes:
        with slot.lock:
            assert slot.proc.stdin is not None and slot.proc.stdout is not None
            slot.proc.stdin.write(struct.pack("<I", len(data)) + data)
            slot.proc.stdin.flush()
            header = slot.proc.stdout.read(4)
            if len(header) < 4:
                raise EOFError("worker closed the pipe")
            (n,) = struct.unpack("<I", header)
            reply = slot.proc.stdout.read(n)
            if len(reply) < n:
                raise EOFError("worker died mid-reply")
            return reply

    def _close_slot(self, slot: _ProcSlot) -> None:
        try:
            if slot.proc.stdin is not None:
                slot.proc.stdin.close()    # EOF: worker loop exits cleanly
            slot.proc.wait(timeout=5)
        except Exception:
            slot.proc.kill()
        try:
            os.unlink(slot.stderr_path)
        except OSError:
            pass

    def _chaos_kill(self, idx: int, slot: _ProcSlot) -> None:
        # SIGKILL from the client side: the worker gets no chance to flush
        # a reply or clean up — the hardest failure the transport can see
        slot.proc.kill()
        try:
            slot.proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            pass

    def _slot_epitaph(self, slot: _ProcSlot) -> str | None:
        try:
            code = slot.proc.wait(timeout=1)
        except subprocess.TimeoutExpired:
            return None
        tail = ""
        try:
            with open(slot.stderr_path, "r", errors="replace") as f:
                tail = f.read()[-2000:].strip()
        except OSError:
            pass
        msg = f"worker process exited (code {code}) mid-request"
        return f"{msg}; stderr tail:\n{tail}" if tail else msg


# --------------------------------------------------------------------- http

def _parse_worker_url(url: str) -> tuple[str, int]:
    """``http://host:port[/...]``, ``host:port``, or ``http://host`` →
    (host, port).  The stdlib transport speaks plain HTTP only."""
    from urllib.parse import urlsplit
    u = urlsplit(url if "//" in url else "//" + url)
    if u.scheme not in ("", "http"):
        raise ValueError(f"worker url {url!r}: only plain http is supported "
                         "by the stdlib transport")
    if not u.hostname:
        raise ValueError(f"worker url {url!r} has no hostname")
    return u.hostname, u.port or 80


class _HttpSlot:
    def __init__(self, host: str, port: int):
        self.host = host
        self.port = port
        self.conn: http.client.HTTPConnection | None = None
        self.lock = threading.Lock()


class HttpBackend(_TransportBackend):
    """The paper's client model: payloads POSTed to a separately-deployed
    worker over pooled keep-alive connections; latency is *measured*."""

    def __init__(self, *, deployment: Deployment | None = None,
                 manifest_path: str | None = None, os_threads: int = 16,
                 url: str | None = None, n_connections: int | None = None,
                 spawn_timeout_s: float = 180.0,
                 chaos: ChaosPlan | None = None, **_):
        if n_connections is None:
            n_connections = max(1, min(os_threads, 8))
        if url is not None and manifest_path is None and deployment is None:
            manifest_path = "<external>"   # worker owns its own manifest
        super().__init__(deployment=deployment, manifest_path=manifest_path,
                         n_workers=n_connections, chaos=chaos)
        self._url = url
        self._spawn_timeout_s = spawn_timeout_s
        self._proc: subprocess.Popen | None = None
        self._addr: tuple[str, int] | None = None
        self._proc_lock = threading.Lock()

    # one worker process serves every connection slot
    def _ensure_worker(self) -> tuple[str, int]:
        with self._proc_lock:
            if self._addr is not None and (
                    self._proc is None or self._proc.poll() is None):
                return self._addr
            if self._url is not None:
                self._addr = _parse_worker_url(self._url)
                return self._addr
            self._addr = self._spawn_worker()
            return self._addr

    def _spawn_worker(self) -> tuple[str, int]:
        from ..runtime.worker_host import READY_MARKER
        self._proc = subprocess.Popen(
            [sys.executable, "-u", "-m", "repro.runtime.worker_host",
             "--manifest", self._manifest_path],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            env=_worker_env(), text=True)
        proc = self._proc
        assert proc.stdout is not None
        # readline() has no timeout of its own: scrape stdout from a helper
        # thread so a worker that hangs *before* printing the READY line
        # (stalled import, wedged manifest read) still trips the deadline
        # instead of blocking every dispatch thread behind _proc_lock.
        lines: "queue_mod.Queue[str | None]" = queue_mod.Queue()

        def scrape():
            for line in iter(proc.stdout.readline, ""):
                lines.put(line)
            lines.put(None)                # EOF: the worker exited

        threading.Thread(target=scrape, daemon=True).start()
        deadline = time.monotonic() + self._spawn_timeout_s
        while True:
            try:
                line = lines.get(timeout=max(0.1,
                                             deadline - time.monotonic()))
            except queue_mod.Empty:
                proc.kill()
                raise TimeoutError(
                    f"http worker not ready within {self._spawn_timeout_s}s"
                ) from None
            if line is None:
                raise WorkerCrash(f"http worker exited during startup "
                                  f"(code {proc.wait()})")
            if line.startswith(READY_MARKER):
                port = int(line.strip().rsplit("port=", 1)[1])
                return ("127.0.0.1", port)

    # ------------------------------------------------------------- slots
    def _spawn_slot(self, idx: int) -> _HttpSlot:
        host, port = self._ensure_worker()
        return _HttpSlot(host, port)

    def _request(self, slot: _HttpSlot, data: bytes) -> bytes:
        with slot.lock:
            if slot.conn is None:
                slot.conn = http.client.HTTPConnection(
                    slot.host, slot.port, timeout=600)
            try:
                slot.conn.request(
                    "POST", "/invoke", body=data,
                    headers={"Content-Type": "application/octet-stream"})
                resp = slot.conn.getresponse()
                body = resp.read()
            except Exception:
                try:
                    slot.conn.close()
                finally:
                    slot.conn = None
                raise
            if resp.status != 200:
                raise WorkerCrash(f"worker HTTP {resp.status}")
            return body

    def _close_slot(self, slot: _HttpSlot) -> None:
        if slot.conn is not None:
            slot.conn.close()

    def _slot_epitaph(self, slot: _HttpSlot) -> str | None:
        with self._proc_lock:
            if self._proc is not None and self._proc.poll() is not None:
                code = self._proc.poll()
                self._addr = None          # force respawn on next slot
                return f"http worker exited (code {code})"
        return None

    def shutdown(self) -> None:
        super().shutdown()
        with self._proc_lock:
            if self._proc is not None and self._proc.poll() is None:
                self._proc.terminate()
                try:
                    self._proc.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    self._proc.kill()
            self._proc = None
