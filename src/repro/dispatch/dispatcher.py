"""The Cppless dispatcher (paper §4.1) — fork-join serverless invocation.

Paper user model::

    cppless::aws_dispatcher dispatcher;
    auto aws = dispatcher.create_instance();      // invocation namespace
    auto fn  = [=] { return pi_estimate(n / np); };
    for (...) cppless::dispatch<config>(aws, fn, result);
    cppless::wait(aws, np);

Here::

    disp = Dispatcher(backend="threads", client="http2_pool")
    inst = disp.create_instance()
    futs = [inst.dispatch(fn) for _ in range(np_)]
    inst.wait()
    results = [f.result() for f in futs]

Dispatchers encapsulate one "cloud" (deployment + execution backend + client
model) so switching backends never touches application code: the execution
strategy is a pluggable ``Backend`` (see ``backends.py``) selected by name —
``"threads"``, ``"inline"``, ``"sim-aws"``, or anything registered.  The
dispatcher itself is a thin *policy* layer: it owns fault tolerance
(idempotent retry on sandbox loss) and straggler mitigation (quantile-
triggered hedged backups), both enabled by the serverless statelessness
contract, while the backend owns execution.

Most application code should use the higher-level ``repro.cloud.Session``
facade, which binds remote functions to a dispatcher and adds streaming
fork-join (``map_unordered`` / ``as_completed`` / ``gather``).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Sequence

from ..core.config import DEFAULT_CONFIG, FunctionConfig
from ..core.deploy import DeployedFunction, Deployment
from ..core.function import RemoteFunction, data_captures
from ..obs import trace as obs_trace
from ..runtime.sandbox import ChaosPlan
from .backends import Backend, resolve_backend
from .cost import CostReport
from .futures import Invocation, InvocationFuture, InvocationRecord
from .latency_model import DEFAULT_LATENCY, LatencyModel
from .retry import RetryPolicy
from .workers import FaultPlan, WorkerCrash


class Dispatcher:
    """One cloud: deployment + pluggable execution backend + client model."""

    def __init__(self, *, backend: str | Backend = "threads",
                 deployment: Deployment | None = None,
                 client: str = "http2_pool",
                 latency: LatencyModel = DEFAULT_LATENCY,
                 max_concurrency: int = 1000, os_threads: int = 16,
                 fault_plan: FaultPlan | None = None,
                 chaos: ChaosPlan | None = None,
                 retry: RetryPolicy | None = None,
                 manifest_path: str | None = None,
                 strict_analysis: bool = False):
        self.deployment = deployment or Deployment(manifest_path=manifest_path)
        self.client = client
        self.latency = latency
        self.max_concurrency = max_concurrency
        # chaos rides next to fault_plan: fault_plan simulates failure in
        # the threaded sandbox, chaos *executes* it against real worker
        # processes (ISSUE 10); retry is the backoff policy both answer to
        self.chaos = chaos
        self.retry = retry if retry is not None else RetryPolicy()
        # the deployment rides along so out-of-process backends can hand
        # workers the manifest to rebuild bridges from
        self.backend = resolve_backend(
            backend, max_concurrency=max_concurrency, os_threads=os_threads,
            fault_plan=fault_plan, latency=latency, client=client,
            chaos=chaos, deployment=self.deployment)
        # shippability analysis knobs: strictness is caller policy; the
        # cross-process bit tells the analyzer whether the fresh-globals
        # contract (RF101) actually bites on this backend — in-process
        # backends run the client's own function object, so it does not
        if strict_analysis:
            self.deployment.strict_analysis = True
        caps = getattr(self.backend, "capabilities", None)
        if caps is not None and hasattr(caps, "cross_process"):
            self.deployment.analysis_cross_process = bool(caps.cross_process)
        self._instances: list[DispatcherInstance] = []

    @property
    def pool(self) -> Backend:
        """Legacy alias for the execution backend."""
        return self.backend

    def create_instance(self) -> "DispatcherInstance":
        inst = DispatcherInstance(self)
        self._instances.append(inst)
        return inst

    def shutdown(self) -> None:
        self.backend.shutdown()


class DispatcherInstance:
    """An invocation namespace (paper: 'acts as a namespace for invocations')."""

    def __init__(self, dispatcher: Dispatcher):
        self.d = dispatcher
        self._next_task = 0
        self._pending: set[int] = set()
        self._cv = threading.Condition()
        self.cost = CostReport()
        self.records: list[InvocationRecord] = []
        self._durations_ms: list[float] = []   # per completed task, for Fig 11
        self._cold: list[bool] = []
        # retry accounting (ISSUE 10): every scheduled resubmission is
        # logged {task_id, attempt, t, backoff_s} — the exponential-spacing
        # evidence chaos tests assert on — and counted against the
        # policy's per-instance budget.
        self.retry_log: list[dict] = []
        self._retries_used = 0

    # ------------------------------------------------------------ dispatch
    def dispatch(self, fn: Callable | RemoteFunction | DeployedFunction,
                 *args: Any, config: FunctionConfig | None = None,
                 **kwargs: Any) -> InvocationFuture:
        """Fire one serverless invocation; returns a future."""
        deployed = self._ensure_deployed(fn, args, kwargs, config)
        captures = (data_captures(deployed.remote_fn.fn)
                    if deployed.remote_fn.fn.__closure__ else {})
        payload = deployed.bridge.pack(tuple(args), kwargs, captures)

        with self._cv:
            task_id = self._next_task
            self._next_task += 1
            self._pending.add(task_id)
        fut = InvocationFuture(task_id)
        # pending-set cleanup rides the future, not the backend completion
        # path: a future cancelled client-side (never executed — backends
        # skip done futures) must still leave ``inflight`` and ``wait()``
        # consistent.  Registered before submit so a synchronous backend
        # (inline) discards through the same path.
        fut.add_done_callback(self._discard_pending)
        cfg = self._resolve_config(fn, config)
        inv = Invocation(task_id=task_id, deployed=deployed, payload=payload,
                         future=fut, config=cfg,
                         on_complete=self._on_complete,
                         deadline=(time.time() + cfg.deadline_s
                                   if cfg.deadline_s is not None else None))
        if obs_trace.TRACER.enabled:
            self._trace_dispatch(inv, deployed)
        self.d.backend.submit(inv)
        return fut

    def _trace_dispatch(self, inv: Invocation, deployed) -> None:
        """Mint the root ``client.submit`` span for a sampled request.

        The span parents under the thread's current context when one is
        bound (the engine loop binds its chunk span around dispatches, so
        worker round-trips nest inside engine spans); otherwise it starts
        a fresh trace, subject to the sampler.  It finishes when the
        future settles — error details (including the worker's traceback,
        the error-context satellite) land as span attributes.
        """
        tracer = obs_trace.TRACER
        parent = tracer.current()
        span = (tracer.span("client.submit", parent) if parent is not None
                else tracer.start_trace("client.submit"))
        if not span:
            return
        span.set("function", deployed.bridge.name)
        span.set("task_id", inv.task_id)
        span.set("payload_bytes", len(inv.payload))
        inv.trace = span.ctx

        def _finish(fut: InvocationFuture) -> None:
            err = fut.exception(timeout=0)
            if err is None:
                span.finish()
                return
            span.set("error.type", type(err).__name__)
            span.set("error.message", str(err))
            rtb = getattr(err, "remote_traceback", "")
            if rtb:
                span.set("error.remote_traceback", rtb)
            hint = getattr(err, "analysis_hint", "")
            if hint:
                span.set("error.analysis", hint[:2000])
            span.finish("error")

        inv.future.add_done_callback(_finish)

    def map_futures(self, fn: Callable | RemoteFunction,
                    arglists: Sequence[tuple],
                    config: FunctionConfig | None = None,
                    hedge_quantile: float | None = None
                    ) -> tuple[list[InvocationFuture], FunctionConfig]:
        """The fork half of ``map``: dispatch all tasks (with hedging armed)
        and hand back the futures — callers that track per-invocation state
        (e.g. shed-mode admission slots) attach to these before joining."""
        futs = [self.dispatch(fn, *a, config=config) for a in arglists]
        cfg = self._resolve_config(fn, config)
        hq = (hedge_quantile if hedge_quantile is not None
              else cfg.hedge_after_quantile)
        if hq is not None and len(futs) > 1:
            self._hedge(fn, arglists, futs, cfg, hq)
        return futs, cfg

    def map(self, fn: Callable | RemoteFunction, arglists: Sequence[tuple],
            config: FunctionConfig | None = None,
            hedge_quantile: float | None = None) -> list[Any]:
        """Fork-join over a task list, with optional straggler hedging.

        Hedging (beyond paper): once ``hedge_quantile`` of tasks completed,
        unfinished tasks get a backup invocation; first result wins.  Safe
        because tasks are stateless and idempotent — the serverless contract.
        """
        futs, cfg = self.map_futures(fn, arglists, config=config,
                                     hedge_quantile=hedge_quantile)
        return [f.result(timeout=cfg.timeout_s) for f in futs]

    @property
    def inflight(self) -> int:
        """Invocations dispatched through this namespace and not yet
        resolved (admission control reads this)."""
        with self._cv:
            return len(self._pending)

    def wait(self, n: int | None = None, timeout: float = 300.0) -> None:
        """Block until all (or the next ``n``) pending invocations resolve."""
        with self._cv:
            if n is None:
                target = 0
                ok = self._cv.wait_for(lambda: not self._pending, timeout)
            else:
                target = max(0, len(self._pending) - n)
                ok = self._cv.wait_for(
                    lambda: len(self._pending) <= target, timeout)
        if not ok:
            raise TimeoutError("wait() timed out")

    # ------------------------------------------------------------ internals
    def _ensure_deployed(self, fn, args, kwargs, config) -> DeployedFunction:
        if isinstance(fn, DeployedFunction):
            return fn
        rf = fn if isinstance(fn, RemoteFunction) else RemoteFunction(fn)
        return self.d.deployment.deploy(rf, *args, config=config, **kwargs)

    @staticmethod
    def _resolve_config(fn, config) -> FunctionConfig:
        if config is not None:
            return config
        if isinstance(fn, RemoteFunction):
            return fn.config
        if isinstance(fn, DeployedFunction):
            return fn.config
        return DEFAULT_CONFIG

    def _on_complete(self, inv: Invocation, ok: bool, value,
                     rec: InvocationRecord) -> None:
        cfg = inv.config or inv.deployed.config
        if not ok and isinstance(value, WorkerCrash) and \
                inv.attempt <= cfg.max_retries:
            # fault tolerance: stateless task → resubmit, same payload —
            # through the backoff policy, never a hot loop (ISSUE 10)
            if self._schedule_retry(inv, rec):
                return
            # retry refused: deadline passed or budget exhausted — the
            # crash surfaces as what it now means to the caller
            if inv.deadline is not None and time.time() >= inv.deadline:
                value = TimeoutError(
                    f"task {inv.task_id} deadline exceeded after "
                    f"{inv.attempt} attempt(s); last failure: {value}")
        # claim → record → resolve: exactly one of a hedge pair wins the
        # claim, and accounting lands BEFORE result() waiters wake —
        # callers joining via map()/gather() must see complete
        # cost/records.  Resolving the future runs its done callbacks,
        # including ``_discard_pending`` (registered first, at dispatch),
        # so wait()-joiners also observe records before waking.
        if not inv.future.claim():
            return                       # hedged sibling already completed
        self._record(rec)
        if ok:
            inv.future.set_result(value, rec)
        else:
            inv.future.set_error(value, rec)

    def _schedule_retry(self, inv: Invocation, rec: InvocationRecord) -> bool:
        """Arrange a backed-off resubmission of a crashed invocation.

        Returns False (caller surfaces the failure) when the deadline has
        passed or the per-instance retry budget is spent.  Otherwise logs
        the retry, starts a daemon timer for ``policy.backoff_s`` and
        returns True — the resubmission re-checks the deadline and the
        future at fire time (a hedged sibling may have won meanwhile, the
        backend may have shut down).
        """
        policy = self.d.retry
        now = time.time()
        if inv.deadline is not None and now >= inv.deadline:
            return False
        with self._cv:
            if policy.budget is not None and \
                    self._retries_used >= policy.budget:
                return False
            self._retries_used += 1
            attempt = inv.attempt + 1
            backoff = policy.backoff_s(inv.task_id, attempt)
            self.retry_log.append({"task_id": inv.task_id, "attempt": attempt,
                                   "t": now, "backoff_s": backoff})
        retry = Invocation(task_id=inv.task_id, deployed=inv.deployed,
                           payload=inv.payload, future=inv.future,
                           attempt=attempt, is_hedge=inv.is_hedge,
                           config=inv.config, on_complete=self._on_complete,
                           trace=inv.trace, deadline=inv.deadline)

        def _resubmit() -> None:
            if retry.future.done():
                return                   # hedged sibling / cancel won the race
            if retry.deadline is not None and time.time() >= retry.deadline:
                if retry.future.claim():
                    self._record(rec)
                    retry.future.set_error(TimeoutError(
                        f"task {retry.task_id} deadline exceeded while "
                        f"backing off before attempt {retry.attempt}"), rec)
                return
            try:
                self.d.backend.submit(retry)
            except Exception as e:       # backend torn down during backoff
                if retry.future.claim():
                    self._record(rec)
                    retry.future.set_error(e, rec)

        timer = threading.Timer(backoff, _resubmit)
        timer.daemon = True
        timer.start()
        return True

    def _discard_pending(self, fut: InvocationFuture) -> None:
        with self._cv:
            self._pending.discard(fut.task_id)
            self._cv.notify_all()

    def _record(self, rec: InvocationRecord | None) -> None:
        if rec is None:
            return
        self.records.append(rec)
        self.cost.add(rec)
        self._durations_ms.append(rec.server_s * 1000.0)
        self._cold.append(rec.cold_start)

    def _hedge(self, fn, arglists, futs, cfg, quantile: float) -> None:
        n = len(futs)
        threshold = max(1, int(n * quantile))
        done_count = threading.Semaphore(0)
        for f in futs:
            f.add_done_callback(lambda _f: done_count.release())
        for _ in range(threshold):
            done_count.acquire()
        # quantile reached: back up every unfinished task
        for f, a in zip(futs, arglists):
            if not f.done():
                deployed = self._ensure_deployed(fn, a, {}, cfg)
                captures = (data_captures(deployed.remote_fn.fn)
                            if deployed.remote_fn.fn.__closure__ else {})
                payload = deployed.bridge.pack(tuple(a), {}, captures)
                backup = Invocation(
                    task_id=f.task_id, deployed=deployed, payload=payload,
                    future=f, is_hedge=True, config=cfg,
                    on_complete=self._on_complete)
                self.d.backend.submit(backup)

    # ------------------------------------------------------------- metrics
    def modeled_latencies_ms(self) -> list[float]:
        """Client-observed latencies for the completed burst (Fig 11 model)."""
        return self.d.latency.simulate_burst(
            self._durations_ms, client=self.d.client, cold=self._cold)

    def modeled_makespan_ms(self) -> float:
        lats = self.modeled_latencies_ms()
        return max(lats) if lats else 0.0


# --------------------------------------------------------- paper-style API --
# Thin compatibility shim: ``instance`` is any invocation namespace — a
# ``DispatcherInstance`` (this module) or a ``repro.cloud.Session`` (the
# redesigned API) — both expose ``dispatch``/``wait``.

def dispatch(instance, fn, *args,
             config: FunctionConfig | None = None) -> InvocationFuture:
    """``cppless::dispatch<config>(aws, fn, result)`` analogue."""
    return instance.dispatch(fn, *args, config=config)


def wait(instance, n: int | None = None) -> None:
    """``cppless::wait(aws, n)`` analogue."""
    instance.wait(n)
