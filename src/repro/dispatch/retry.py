"""Unified retry/backoff/deadline policy + circuit breaker (ISSUE 10).

The dispatcher's original fault-tolerance loop resubmitted a crashed task
*immediately* — correct for the simulated sandbox (where a "crash" is a
dice roll and the pool is healthy), but a hot loop against real failure:
a dead worker subprocess takes tens of milliseconds to respawn, and every
immediate retry lands on the still-cold slot, burning attempts that a
short wait would have saved.  This module is the policy that replaces it:

* :class:`RetryPolicy` — seeded exponential backoff with deterministic
  jitter, a per-instance retry *budget* (a flapping fleet cannot consume
  unbounded resubmissions), and the deadline gate (never resubmit work
  that cannot finish before its deadline).
* :class:`CircuitBreaker` — per-member failure tripwire for the fleet
  router: a member that keeps crashing stops receiving routes for a
  cooldown instead of eating the shared retry budget, then readmits via a
  half-open probe.

Determinism contract: ``backoff_s(task_id, attempt)`` is a pure function
of ``(seed, task_id, attempt)`` — the same chaos seed replays the same
retry schedule (the same hash-the-coordinates trick ``FaultPlan.roll``
uses).  With ``jitter <= 0.5`` the schedule is monotone: the *shortest*
possible backoff of attempt N+1 is at least the *longest* of attempt N,
so recorded retry timestamps are exponentially spaced by construction.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass


@dataclass(frozen=True)
class RetryPolicy:
    """Backoff schedule + retry budget for ``WorkerCrash`` resubmission.

    ``backoff_s(task_id, attempt)`` gives the delay before submitting
    ``attempt`` (numbered like ``Invocation.attempt``: the first *retry*
    is attempt 2).  ``budget`` caps total retries per dispatcher instance
    across all tasks; ``None`` leaves only per-task ``max_retries``.
    """

    base_s: float = 0.02          # backoff before attempt 2
    multiplier: float = 2.0       # exponential growth per further attempt
    max_backoff_s: float = 2.0    # ceiling (keeps tail retries bounded)
    jitter: float = 0.5           # fraction shaved off deterministically
    budget: int | None = None     # per-instance retry budget (None = ∞)
    seed: int = 0                 # replays the exact jitter sequence

    def backoff_s(self, task_id: int, attempt: int) -> float:
        raw = min(self.max_backoff_s,
                  self.base_s * self.multiplier ** max(0, attempt - 2))
        rng = random.Random(self.seed * 1_000_003 + task_id * 1009 + attempt)
        return raw * (1.0 - self.jitter * rng.random())


class CircuitBreaker:
    """closed → open → half-open failure tripwire (per fleet member).

    * ``closed``: traffic flows; ``threshold`` consecutive failures open it.
    * ``open``: ``allow()`` refuses for ``cooldown_s``, then transitions to
      half-open and admits exactly one probe.
    * ``half-open``: further ``allow()`` calls refuse while the probe is in
      flight; a failure re-opens, a success — or a quiet ``probe_window_s``
      (the probe's owner never reported back) — closes.

    The clock is injectable so breaker unit tests drive transitions
    without sleeping; callers may also pass ``now=`` explicitly.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 0.25,
                 probe_window_s: float | None = None,
                 clock=time.monotonic):
        self.threshold = max(1, threshold)
        self.cooldown_s = cooldown_s
        self.probe_window_s = (cooldown_s if probe_window_s is None
                               else probe_window_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._failures = 0
        self._opened_at = 0.0
        self._probe_at = 0.0
        self.opens = 0                # lifetime open transitions (observability)

    @property
    def state(self) -> str:
        return self._state

    def record_failure(self, now: float | None = None) -> None:
        now = self._clock() if now is None else now
        with self._lock:
            self._failures += 1
            if self._state == self.HALF_OPEN or (
                    self._state == self.CLOSED
                    and self._failures >= self.threshold):
                if self._state != self.OPEN:
                    self.opens += 1
                self._state = self.OPEN
                self._opened_at = now

    def record_success(self, now: float | None = None) -> None:
        with self._lock:
            self._failures = 0
            self._state = self.CLOSED

    def allow(self, now: float | None = None) -> bool:
        """May this member receive traffic right now?"""
        now = self._clock() if now is None else now
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN:
                if now - self._opened_at >= self.cooldown_s:
                    self._state = self.HALF_OPEN   # admit one probe
                    self._probe_at = now
                    return True
                return False
            # half-open: hold the line while the probe is in flight; a
            # quiet window means the probe's route never failed — close
            if now - self._probe_at >= self.probe_window_s:
                self._state = self.CLOSED
                self._failures = 0
                return True
            return False

    def snapshot(self) -> dict:
        with self._lock:
            return {"state": self._state, "failures": self._failures,
                    "opens": self.opens}
