from .backends import (Backend, InlineBackend, SimAWSBackend, ThreadsBackend,
                       available_backends, register_backend, resolve_backend)
from .cost import PRICE_PER_GB_S, PRICE_PER_REQUEST, CostReport
from .dispatcher import Dispatcher, DispatcherInstance, dispatch, wait
from .futures import (Invocation, InvocationCancelled, InvocationFuture,
                      InvocationRecord, as_completed, gather)
from .latency_model import DEFAULT_LATENCY, LatencyModel
from .transports import HttpBackend, ProcessesBackend
from .workers import (BackendCapabilities, FaultPlan, WorkerCrash,
                      WorkerPool)

__all__ = [
    "Dispatcher", "DispatcherInstance", "dispatch", "wait", "CostReport",
    "InvocationFuture", "InvocationRecord", "Invocation",
    "InvocationCancelled", "LatencyModel",
    "DEFAULT_LATENCY", "WorkerPool", "WorkerCrash", "FaultPlan",
    "PRICE_PER_GB_S", "PRICE_PER_REQUEST",
    "Backend", "BackendCapabilities", "ThreadsBackend", "InlineBackend",
    "SimAWSBackend", "ProcessesBackend", "HttpBackend",
    "register_backend", "resolve_backend",
    "available_backends", "as_completed", "gather",
]
