"""Elastic worker pool — the in-process execution backend for the FaaS fleet.

Real execution, simulated fleet: invocations run on a bounded set of OS
threads, while sandbox lifecycle (cold/warm accounting, fault injection,
billing stats) lives in the reusable :class:`repro.runtime.sandbox.SandboxHost`
— the same host the out-of-process transports (``processes``/``http``) and
the worker-side :class:`~repro.runtime.worker_host.WorkerHost` use.  The
serverless execution contract is enforced: a task sees only its payload
bytes (``Bridge.entry``), is stateless, and may be killed and retried at
any time.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

from ..runtime.sandbox import (FaultPlan, SandboxHost, WorkerCrash,
                               WorkerInstance)
from .futures import Invocation, InvocationRecord

__all__ = ["BackendCapabilities", "FaultPlan", "WorkerCrash",
           "WorkerInstance", "WorkerPool", "fill_record"]


@dataclass(frozen=True)
class BackendCapabilities:
    """What an execution backend can do — policy layers branch on these
    instead of isinstance checks (see ``dispatch.backends.Backend``)."""
    concurrent: bool = True        # real OS-thread parallelism
    warm_reuse: bool = True        # sandbox cold/warm bookkeeping
    fault_injection: bool = False  # honors a FaultPlan
    models_latency: bool = False   # fills InvocationRecord.modeled_latency_ms
    measures_latency: bool = False # modeled_latency_ms is a *measurement*
    cross_process: bool = False    # payloads cross a process/socket boundary
    # worker-resident state (ISSUE 5): entries in repro.runtime.state
    # survive between invocations and FunctionConfig.affinity pinning is
    # honored (trivially, for in-process backends) — iteration-level
    # serving requires this; backends without it get the wave fallback
    resident_state: bool = False


def fill_record(rec: InvocationRecord, *, stats, server_s: float,
                worker_id: int, cold_start: bool, result_bytes: int) -> None:
    """Copy one completed entry's accounting into an invocation record —
    shared by every transport so records look identical across backends."""
    rec.worker_id = worker_id
    rec.cold_start = cold_start
    rec.server_s = server_s
    rec.result_bytes = result_bytes
    if isinstance(stats, dict):
        rec.deserialize_s = stats.get("deserialize_s", 0.0)
        rec.compute_s = stats.get("compute_s", 0.0)
        rec.serialize_s = stats.get("serialize_s", 0.0)
    else:
        rec.deserialize_s = stats.deserialize_s
        rec.compute_s = stats.compute_s
        rec.serialize_s = stats.serialize_s


class WorkerPool:
    """Elastic pool executing ``Invocation``s on OS threads.

    ``max_concurrency`` models the account's function-concurrency limit
    (paper: 1000); ``os_threads`` bounds real parallelism in this container.
    Sandboxes scale out on demand (cold start) and are reused warm, per
    function name — matching FaaS semantics — via the ``SandboxHost``.

    ``WorkerPool`` is the ``"threads"`` backend of the registry in
    ``dispatch.backends``; subclasses there reuse its sandbox model with
    different execution strategies (inline, simulated-AWS).
    """

    capabilities = BackendCapabilities(concurrent=True, warm_reuse=True,
                                       fault_injection=True,
                                       resident_state=True)

    def __init__(self, max_concurrency: int = 1000, os_threads: int = 16,
                 fault_plan: FaultPlan | None = None):
        self.max_concurrency = max_concurrency
        self.sandboxes = SandboxHost(fault_plan)
        self._queue: "queue.Queue[Invocation | None]" = queue.Queue()
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._stop = False
        self._resize(os_threads)

    @property
    def fault_plan(self) -> FaultPlan:
        return self.sandboxes.fault_plan

    # ------------------------------------------------------------- elastic
    def _resize(self, n: int) -> None:
        while len(self._threads) < n:
            t = threading.Thread(target=self._run, daemon=True)
            t.start()
            self._threads.append(t)

    def scale_to(self, os_threads: int) -> None:
        """Elastic scale-out of real executors (scale-in is cooperative)."""
        self._resize(os_threads)

    def drain_warm(self, function_name: str | None = None) -> int:
        """Scale-in: drop warm sandboxes (next invocations pay cold starts)."""
        return self.sandboxes.drain(function_name)

    def stats(self) -> dict:
        """Fleet observability: the pool's cold/warm and busy accounting
        plus the (process-local) resident-state registry — the in-process
        shape of ``_TransportBackend.stats()``."""
        from ..runtime import state
        s = dict(self.sandboxes.stats())
        st = state.stats()
        return {"n_workers": max(1, len(self._threads)), "spawned": 1,
                "workers": {0: {"sandboxes": s, "state": st}},
                "cold_starts": s["cold_starts"], "warm_hits": s["warm_hits"],
                "busy_s": s["busy_s"], "state_handles": st["count"]}

    # ------------------------------------------------------------ dispatch
    def submit(self, inv: Invocation) -> None:
        self._queue.put(inv)

    @property
    def queue_depth(self) -> int:
        """Invocations accepted but not yet started (admission control)."""
        return self._queue.qsize()

    def shutdown(self) -> None:
        self._stop = True
        for _ in self._threads:
            self._queue.put(None)

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while not self._stop:
            inv = self._queue.get()
            if inv is None:
                return
            if inv.future.done():       # hedged sibling already won
                self._skipped(inv)
                continue
            try:
                self._execute(inv)
            except BaseException as e:  # executor bug must not kill the thread
                inv.future.set_error(e)

    # Subclass hooks (see dispatch.backends): called for every invocation
    # that is dropped unexecuted / right before its completion is delivered.
    def _skipped(self, inv: Invocation) -> None:
        pass

    def _post_execute(self, inv: Invocation, rec: InvocationRecord,
                      ok: bool) -> None:
        pass

    def _execute(self, inv: Invocation) -> None:
        bridge = inv.deployed.bridge
        rec = InvocationRecord(
            task_id=inv.task_id, function_name=bridge.name,
            attempts=inv.attempt, hedged=inv.is_hedge,
            payload_bytes=len(inv.payload),
            memory_gb=bridge.config.memory_gb)

        def finish(ok: bool, value, record: InvocationRecord) -> None:
            self._post_execute(inv, record, ok)
            if inv.on_complete is not None:
                inv.on_complete(inv, ok, value, record)
            elif ok:
                inv.future.set_result(value, record)
            else:
                inv.future.set_error(value, record)

        # the in-process analogue of the worker-side entry span: same name
        # ("worker.entry"), same parent (the request's submit span), so a
        # trace looks the same whether the entry ran in a thread or a child
        # process
        from ..obs import trace as obs_trace
        espan = (obs_trace.TRACER.span("worker.entry", inv.trace,
                                       function=bridge.name)
                 if inv.trace is not None else obs_trace.NOOP)
        try:
            done = self.sandboxes.invoke(
                bridge.entry, bridge.name, inv.payload,
                task_id=inv.task_id, attempt=inv.attempt)
            fill_record(rec, stats=done.stats, server_s=done.server_s,
                        worker_id=done.worker_id, cold_start=done.cold_start,
                        result_bytes=len(done.blob))
            espan.set("cold_start", done.cold_start)
            espan.set("worker_id", done.worker_id)
            espan.finish()
            finish(True, bridge.unpack_result(done.blob), rec)
        except WorkerCrash as e:
            self._stamp_failure(rec, e)
            espan.set("error.type", type(e).__name__)
            espan.finish("error")
            finish(False, e, rec)          # dispatcher decides on retry
        except BaseException as e:         # user-code error: no retry
            self._stamp_failure(rec, e)
            rec.server_s = 0.0
            espan.set("error.type", type(e).__name__)
            espan.set("error.message", str(e))
            espan.finish("error")
            finish(False, e, rec)

    @staticmethod
    def _stamp_failure(rec: InvocationRecord, e: BaseException) -> None:
        # the sandbox host rode its accounting on the exception: crash and
        # error records still identify the (cold?) sandbox that burned
        rec.worker_id = getattr(e, "sandbox_worker_id", rec.worker_id)
        rec.cold_start = getattr(e, "sandbox_cold_start", rec.cold_start)
