"""Elastic worker pool — the execution backend standing in for the FaaS fleet.

Real execution, simulated fleet: invocations run on a bounded set of OS
threads, while *worker instances* (= Lambda sandboxes) are bookkeeping objects
that model cold starts, warm reuse, elastic scale-out/in, and failures.  The
serverless execution contract is enforced: a task sees only its payload bytes
(``Bridge.entry``), is stateless, and may be killed and retried at any time.
"""
from __future__ import annotations

import queue
import random
import threading
import time
from dataclasses import dataclass, field

from .futures import Invocation, InvocationRecord


class WorkerCrash(RuntimeError):
    """Injected sandbox failure (node loss) — retried by the dispatcher."""


@dataclass(frozen=True)
class BackendCapabilities:
    """What an execution backend can do — policy layers branch on these
    instead of isinstance checks (see ``dispatch.backends.Backend``)."""
    concurrent: bool = True        # real OS-thread parallelism
    warm_reuse: bool = True        # sandbox cold/warm bookkeeping
    fault_injection: bool = False  # honors a FaultPlan
    models_latency: bool = False   # fills InvocationRecord.modeled_latency_ms


@dataclass
class WorkerInstance:
    worker_id: int
    function_name: str
    invocations: int = 0
    created_at: float = field(default_factory=time.time)

    @property
    def is_cold(self) -> bool:
        return self.invocations == 0


@dataclass
class FaultPlan:
    """Deterministic fault/straggler injection for tests and benchmarks."""
    failure_rate: float = 0.0          # P(sandbox crash) per invocation
    straggler_rate: float = 0.0        # P(task straggles)
    straggler_factor: float = 8.0      # straggler duration multiplier
    straggler_sleep_s: float = 0.0     # real extra sleep for stragglers
    seed: int = 0

    def roll(self, task_id: int, attempt: int) -> tuple[bool, bool]:
        rng = random.Random(self.seed * 1_000_003 + task_id * 1009 + attempt)
        fail = rng.random() < self.failure_rate
        straggle = rng.random() < self.straggler_rate
        return fail, straggle


class WorkerPool:
    """Elastic pool executing ``Invocation``s on OS threads.

    ``max_concurrency`` models the account's function-concurrency limit
    (paper: 1000); ``os_threads`` bounds real parallelism in this container.
    Instances scale out on demand (cold start) and are reused warm, per
    function name — matching FaaS semantics.

    ``WorkerPool`` is the ``"threads"`` backend of the registry in
    ``dispatch.backends``; subclasses there reuse its sandbox model with
    different execution strategies (inline, simulated-AWS).
    """

    capabilities = BackendCapabilities(concurrent=True, warm_reuse=True,
                                       fault_injection=True)

    def __init__(self, max_concurrency: int = 1000, os_threads: int = 16,
                 fault_plan: FaultPlan | None = None):
        self.max_concurrency = max_concurrency
        self.fault_plan = fault_plan or FaultPlan()
        self._queue: "queue.Queue[Invocation | None]" = queue.Queue()
        self._warm: dict[str, list[WorkerInstance]] = {}
        self._next_worker_id = 0
        self._live_instances = 0
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._stop = False
        self._resize(os_threads)

    # ------------------------------------------------------------- elastic
    def _resize(self, n: int) -> None:
        while len(self._threads) < n:
            t = threading.Thread(target=self._run, daemon=True)
            t.start()
            self._threads.append(t)

    def scale_to(self, os_threads: int) -> None:
        """Elastic scale-out of real executors (scale-in is cooperative)."""
        self._resize(os_threads)

    def drain_warm(self, function_name: str | None = None) -> int:
        """Scale-in: drop warm sandboxes (next invocations pay cold starts)."""
        with self._lock:
            if function_name is None:
                n = sum(len(v) for v in self._warm.values())
                self._warm.clear()
            else:
                n = len(self._warm.pop(function_name, []))
            self._live_instances -= n
            return n

    # ------------------------------------------------------------ dispatch
    def submit(self, inv: Invocation) -> None:
        self._queue.put(inv)

    def shutdown(self) -> None:
        self._stop = True
        for _ in self._threads:
            self._queue.put(None)

    # ------------------------------------------------------------- worker
    def _acquire_instance(self, fname: str) -> tuple[WorkerInstance, bool]:
        with self._lock:
            warm = self._warm.setdefault(fname, [])
            if warm:
                inst = warm.pop()
                return inst, False
            self._next_worker_id += 1
            self._live_instances += 1
            return WorkerInstance(self._next_worker_id, fname), True

    def _release_instance(self, inst: WorkerInstance) -> None:
        with self._lock:
            self._warm.setdefault(inst.function_name, []).append(inst)

    def _run(self) -> None:
        while not self._stop:
            inv = self._queue.get()
            if inv is None:
                return
            if inv.future.done():       # hedged sibling already won
                self._skipped(inv)
                continue
            try:
                self._execute(inv)
            except BaseException as e:  # executor bug must not kill the thread
                inv.future.set_error(e)

    # Subclass hooks (see dispatch.backends): called for every invocation
    # that is dropped unexecuted / right before its completion is delivered.
    def _skipped(self, inv: Invocation) -> None:
        pass

    def _post_execute(self, inv: Invocation, rec: InvocationRecord,
                      ok: bool) -> None:
        pass

    def _execute(self, inv: Invocation) -> None:
        bridge = inv.deployed.bridge
        fail, straggle = self.fault_plan.roll(inv.task_id, inv.attempt)
        inst, cold = self._acquire_instance(bridge.name)
        rec = InvocationRecord(
            task_id=inv.task_id, function_name=bridge.name,
            worker_id=inst.worker_id, cold_start=cold, attempts=inv.attempt,
            hedged=inv.is_hedge, payload_bytes=len(inv.payload),
            memory_gb=bridge.config.memory_gb)
        def finish(ok: bool, value, record: InvocationRecord) -> None:
            self._post_execute(inv, record, ok)
            if inv.on_complete is not None:
                inv.on_complete(inv, ok, value, record)
            elif ok:
                inv.future.set_result(value, record)
            else:
                inv.future.set_error(value, record)

        try:
            if fail:
                with self._lock:       # crashed sandbox is never reused
                    self._live_instances -= 1
                raise WorkerCrash(
                    f"sandbox {inst.worker_id} lost (task {inv.task_id} "
                    f"attempt {inv.attempt})")
            t0 = time.perf_counter()
            # stats come back with the blob: concurrent entries of the same
            # bridge must not read each other's accounting (shared-attr race)
            blob, stats = bridge.entry(inv.payload)
            server_s = time.perf_counter() - t0
            if straggle:
                if self.fault_plan.straggler_sleep_s:
                    time.sleep(self.fault_plan.straggler_sleep_s)
                server_s *= self.fault_plan.straggler_factor
            rec.deserialize_s = stats.deserialize_s
            rec.compute_s = stats.compute_s
            rec.serialize_s = stats.serialize_s
            rec.server_s = server_s
            rec.result_bytes = len(blob)
            inst.invocations += 1
            self._release_instance(inst)
            finish(True, bridge.unpack_result(blob), rec)
        except WorkerCrash as e:
            finish(False, e, rec)          # dispatcher decides on retry
        except BaseException as e:         # user-code error: no retry
            rec.server_s = 0.0
            finish(False, e, rec)
