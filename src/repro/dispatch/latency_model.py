"""Client latency model, calibrated to the paper's measurements (Fig 11).

The paper's HTTP/2 client: 16 connections × 100 concurrent streams, round-
robin assignment; ~50 ms single warm invocation; latency grows ~linearly to
~150 ms as concurrency approaches the stream budget; past the budget,
invocations queue until a pending response frees a stream; dispatch proceeds
at ~10 invocations/ms after connection setup.  The HTTP/1.1 (Boost.Beast)
client opens a TCP connection per request and is limited by the process fd
space, with a higher per-request cost.

This module is *accounting only* — execution is real (worker pool); the model
maps measured server durations to the client-observed latency a cloud
deployment would see.  ``simulate_burst`` is a discrete-event simulation used
both by the dispatcher's metrics and by the Fig 11 benchmark.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass


@dataclass(frozen=True)
class LatencyModel:
    # connection setup, paid once per connection at first use
    connect_ms: float = 10.0
    # client+network+API overhead for one warm invocation (no server time)
    invoke_rtt_ms: float = 30.0
    # extra per-request cost for the HTTP/1.1 client (TCP+TLS handshake)
    http1_handshake_ms: float = 28.0
    # client dispatch rate after connection setup (paper: ~10 inv/ms)
    dispatch_rate_per_ms: float = 10.0
    # marginal client-side cost per additional in-flight invocation
    # (paper: 50 ms → ~150 ms near 1000–1600 concurrent ⇒ ~0.065 ms each)
    congestion_ms_per_inflight: float = 0.065
    # cold start (new sandbox provisioning)
    cold_start_ms: float = 180.0
    # pooled (HTTP/2) client shape
    n_connections: int = 16
    streams_per_connection: int = 100
    # per-request (HTTP/1.1) client shape
    fd_limit: int = 1024

    def capacity(self, client: str) -> int:
        if client == "http2_pool":
            return self.n_connections * self.streams_per_connection
        if client == "http1_per_request":
            return self.fd_limit
        raise ValueError(f"unknown client {client!r}")

    def per_invoke_overhead_ms(self, client: str) -> float:
        if client == "http2_pool":
            return self.invoke_rtt_ms
        return self.invoke_rtt_ms + self.http1_handshake_ms

    def simulate_burst(self, durations_ms: list[float], client: str = "http2_pool",
                       cold: list[bool] | None = None) -> list[float]:
        """Client-observed latency for a burst of K concurrent invocations.

        Discrete-event: invocation i is issued at ``i / dispatch_rate`` once a
        stream is free; completion frees its stream.  Returns latencies in
        submit order (latency = completion − submit-time-0 for the burst, as
        the paper's Fig 11 plots per-invocation latency within one burst).
        """
        cap = self.capacity(client)
        rtt = self.per_invoke_overhead_ms(client)
        k = len(durations_ms)
        cold = cold or [False] * k
        # connection setup amortized: pooled client pays for its pool once,
        # per-request client pays per request (captured in handshake term).
        setup = self.connect_ms if client == "http2_pool" else self.connect_ms
        free_at: list[float] = []      # completion times of in-flight (heap)
        out: list[float] = []
        for i, dur in enumerate(durations_ms):
            issue = setup + i / self.dispatch_rate_per_ms
            if len(free_at) >= cap:
                earliest = heapq.heappop(free_at)
                issue = max(issue, earliest)
            inflight = len(free_at) + 1
            lat = (rtt + dur
                   + (self.cold_start_ms if cold[i] else 0.0)
                   + self.congestion_ms_per_inflight * min(inflight, cap))
            done = issue + lat
            heapq.heappush(free_at, done)
            out.append(done)           # client-observed: burst start → done
        return out


DEFAULT_LATENCY = LatencyModel()
