"""Tiled Monte-Carlo ray tracer (paper §5.3, Figs 1/14).

"Ray Tracing in One Weekend"-style random sphere scene: lambertian + metal
materials, sky gradient, gamma 2.  Fully vectorized over a tile's pixels;
bounces via ``lax.scan`` over depth with active-ray masking (the JAX
adaptation of the paper's AVX2 vectorization — the insight "vectorize the
per-pixel loop" maps to the VPU the same way).

The image is split into TxT tiles; each tile is a serverless task whose
payload carries the (serialized) scene — ~tens of KiB, matching the paper's
~88 KiB/invocation observation — and tasks are heterogeneous because
per-tile object coverage varies: the straggler effect of Fig 1.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..cloud import Session, as_completed, session_scope
from ..dispatch import Dispatcher


@dataclass
class Scene:
    center: np.ndarray     # (N, 3)
    radius: np.ndarray     # (N,)
    albedo: np.ndarray     # (N, 3)
    fuzz: np.ndarray       # (N,)  metal fuzz; <0 => lambertian
    # camera
    origin: np.ndarray     # (3,)
    look_at: np.ndarray    # (3,)
    vfov: float
    width: int
    height: int


def random_scene(n_spheres: int = 48, seed: int = 7, width: int = 128,
                 height: int = 128) -> Scene:
    rng = np.random.default_rng(seed)
    pos = rng.uniform(-6, 6, (n_spheres, 2))
    center = np.stack([pos[:, 0],
                       rng.uniform(0.2, 0.5, n_spheres), pos[:, 1]], -1)
    radius = rng.uniform(0.2, 0.5, n_spheres)
    albedo = rng.uniform(0.1, 0.95, (n_spheres, 3))
    fuzz = np.where(rng.random(n_spheres) < 0.3,
                    rng.uniform(0.0, 0.4, n_spheres), -1.0)
    # ground sphere
    center = np.vstack([center, [[0.0, -1000.0, 0.0]]])
    radius = np.append(radius, 1000.0)
    albedo = np.vstack([albedo, [[0.5, 0.5, 0.5]]])
    fuzz = np.append(fuzz, -1.0)
    return Scene(center.astype(np.float32), radius.astype(np.float32),
                 albedo.astype(np.float32), fuzz.astype(np.float32),
                 origin=np.array([0, 2.2, 9.0], np.float32),
                 look_at=np.array([0, 0.6, 0], np.float32),
                 vfov=35.0, width=width, height=height)


def _hit(center, radius, ro, rd, t_min=1e-3, t_max=1e9):
    """Nearest sphere hit.  ro/rd (P,3); returns (t, idx, hit_mask)."""
    oc = ro[:, None, :] - center[None, :, :]            # (P,N,3)
    a = jnp.sum(rd * rd, -1)[:, None]
    half_b = jnp.sum(oc * rd[:, None, :], -1)
    c = jnp.sum(oc * oc, -1) - radius[None, :] ** 2
    disc = half_b * half_b - a * c
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    t0 = (-half_b - sq) / a
    t1 = (-half_b + sq) / a
    t = jnp.where((t0 > t_min) & (disc > 0), t0,
                  jnp.where((t1 > t_min) & (disc > 0), t1, t_max))
    idx = jnp.argmin(t, -1)
    tbest = jnp.take_along_axis(t, idx[:, None], 1)[:, 0]
    return tbest, idx, tbest < t_max * 0.5


def _trace(scene_arrays, ro, rd, key, max_depth: int = 8):
    center, radius, albedo, fuzz = scene_arrays
    p = ro.shape[0]
    atten = jnp.ones((p, 3), jnp.float32)
    color = jnp.zeros((p, 3), jnp.float32)
    active = jnp.ones((p,), bool)

    def bounce(carry, k):
        ro, rd, atten, color, active = carry
        t, idx, hit = _hit(center, radius, ro, rd)
        hitp = ro + t[:, None] * rd
        n = (hitp - center[idx]) / radius[idx][:, None]
        outward = jnp.sum(n * rd, -1) < 0
        n = jnp.where(outward[:, None], n, -n)

        # sky for rays that miss
        unit = rd / jnp.linalg.norm(rd, axis=-1, keepdims=True)
        tt = 0.5 * (unit[:, 1] + 1.0)
        sky = (1 - tt[:, None]) * jnp.ones(3) + tt[:, None] * jnp.asarray(
            [0.5, 0.7, 1.0])
        color = color + jnp.where((active & ~hit)[:, None],
                                  atten * sky, 0.0)

        # scatter: lambertian or metal
        u = jax.random.normal(k, (p, 3))
        u = u / (jnp.linalg.norm(u, axis=-1, keepdims=True) + 1e-8)
        diff_dir = n + u
        refl = rd - 2 * jnp.sum(rd * n, -1, keepdims=True) * n
        is_metal = fuzz[idx] >= 0
        new_rd = jnp.where(is_metal[:, None],
                           refl + fuzz[idx][:, None] * u, diff_dir)
        atten = jnp.where((active & hit)[:, None], atten * albedo[idx],
                          atten)
        active = active & hit & (jnp.sum(new_rd * n, -1) > 0)
        return (hitp + 1e-3 * n, new_rd, atten, color, active), None

    keys = jax.random.split(key, max_depth)
    (ro, rd, atten, color, active), _ = jax.lax.scan(
        bounce, (ro, rd, atten, color, active), keys)
    return color


def render_tile(scene_arrays, cam, x0: int, y0: int, tile: int,
                width: int, height: int, spp: int, seed):
    """Render one (tile × tile) block -> (tile, tile, 3) float32."""
    origin, lower_left, horiz, vert = cam
    xs = x0 + jnp.arange(tile)
    ys = y0 + jnp.arange(tile)
    px, py = jnp.meshgrid(xs, ys)                    # (T,T)
    px = px.reshape(-1).astype(jnp.float32)
    py = py.reshape(-1).astype(jnp.float32)
    key = jax.random.PRNGKey(seed)

    def sample(carry, k):
        acc = carry
        k1, k2, k3 = jax.random.split(k, 3)
        du = jax.random.uniform(k1, px.shape)
        dv = jax.random.uniform(k3, py.shape)
        u = (px + du) / width
        v = 1.0 - (py + dv) / height
        rd = (lower_left + u[:, None] * horiz + v[:, None] * vert - origin)
        ro = jnp.broadcast_to(origin, rd.shape)
        col = _trace(scene_arrays, ro, rd, k2)
        return acc + col, None

    acc, _ = jax.lax.scan(sample, jnp.zeros((tile * tile, 3)),
                          jax.random.split(key, spp))
    img = jnp.sqrt(jnp.clip(acc / spp, 0.0, 1.0))    # gamma 2
    return img.reshape(tile, tile, 3)


def camera(scene: Scene):
    aspect = scene.width / scene.height
    theta = np.radians(scene.vfov)
    h = np.tan(theta / 2)
    vh, vw = 2 * h, 2 * h * aspect
    w = scene.origin - scene.look_at
    w = w / np.linalg.norm(w)
    u = np.cross([0, 1, 0], w)
    u = u / np.linalg.norm(u)
    v = np.cross(w, u)
    horiz = (vw * u).astype(np.float32)
    vert = (vh * v).astype(np.float32)
    ll = scene.origin - horiz / 2 - vert / 2 - w
    return (jnp.asarray(scene.origin), jnp.asarray(ll.astype(np.float32)),
            jnp.asarray(horiz), jnp.asarray(vert))


def render_serial(scene: Scene, spp: int = 4):
    arrays = (jnp.asarray(scene.center), jnp.asarray(scene.radius),
              jnp.asarray(scene.albedo), jnp.asarray(scene.fuzz))
    cam = camera(scene)
    return np.asarray(render_tile(arrays, cam, 0, 0, scene.width,
                                  scene.width, scene.height, spp, 0)
                      )[:scene.height, :scene.width]


def render_serverless(scene: Scene, tile: int = 32, spp: int = 4,
                      dispatcher: Dispatcher | None = None,
                      session: Session | None = None):
    """One serverless task per tile (paper Fig 1); returns (img, session).

    Tiles are blitted into the framebuffer in *completion* order
    (streaming fork-join): fast sky tiles land while the dense-geometry
    stragglers of Fig 1 are still tracing.
    """
    with session_scope(session, dispatcher) as sess:
        arrays = tuple(np.asarray(a) for a in
                       (scene.center, scene.radius, scene.albedo, scene.fuzz))
        cam = camera(scene)
        w, h = scene.width, scene.height

        def task(x0, y0, seed):
            return render_tile(tuple(jnp.asarray(a) for a in arrays), cam,
                               x0, y0, tile, w, h, spp, seed)

        render = sess.function(task, name=f"rt_tile{tile}", memory_mb=1024)
        coords = [(x, y) for y in range(0, h, tile)
                  for x in range(0, w, tile)]
        futs = {render.submit(jnp.int32(x), jnp.int32(y), jnp.int32(i)):
                (x, y) for i, (x, y) in enumerate(coords)}
        img = np.zeros((h, w, 3), np.float32)
        for f in as_completed(futs):
            x, y = futs[f]
            t = np.asarray(f.result())
            img[y:y + tile, x:x + tile] = t[: h - y, : w - x]
    return img, sess
