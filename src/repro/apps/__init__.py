from .nqueens import KNOWN, count_completions, prefixes, solve_serial, \
    solve_serverless
from .pi import compute_pi, pi_estimate
from .raytracer import Scene, camera, random_scene, render_serial, \
    render_serverless
