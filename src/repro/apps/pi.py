"""Parallel Monte-Carlo PI — the paper's walkthrough example (Fig 6).

    auto fn = [=] { return pi_estimate(n / np); };
    for (...) cppless::dispatch<config>(aws, fn, result);

Here the same shape: a jax-traceable task closed over its sample count,
dispatched np_ times, reduced on the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import FunctionConfig, RemoteFunction
from ..dispatch import Dispatcher


def pi_estimate(n: int, seed):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (n,))
    y = jax.random.uniform(ky, (n,))
    inside = jnp.sum((x * x + y * y) <= 1.0)
    return 4.0 * inside / n


def compute_pi(n: int = 1_000_000, np_: int = 32,
               dispatcher: Dispatcher | None = None) -> float:
    """Offload np_ estimation tasks; average the results (paper Fig 6)."""
    d = dispatcher or Dispatcher()
    inst = d.create_instance()
    per = n // np_
    fn = RemoteFunction(lambda seed: pi_estimate(per, seed),
                        name="pi_estimate",
                        config=FunctionConfig(memory_mb=512))
    futs = [inst.dispatch(fn, i) for i in range(np_)]
    inst.wait()
    vals = [float(f.result()) for f in futs]
    return sum(vals) / len(vals), inst
