"""Parallel Monte-Carlo PI — the paper's walkthrough example (Fig 6).

    auto fn = [=] { return pi_estimate(n / np); };
    for (...) cppless::dispatch<config>(aws, fn, result);

Here the same shape through the session API: a jax-traceable task closed
over its sample count, bound to a ``cloud.Session``, fanned out ``np_``
times, reduced on the host.  The backend (threads / inline / sim-aws) is a
session argument — the application code never changes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..cloud import Session, session_scope
from ..dispatch import Dispatcher


def pi_estimate(n: int, seed):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (n,))
    y = jax.random.uniform(ky, (n,))
    inside = jnp.sum((x * x + y * y) <= 1.0)
    return 4.0 * inside / n


def compute_pi(n: int = 1_000_000, np_: int = 32,
               dispatcher: Dispatcher | None = None,
               session: Session | None = None) -> tuple[float, Session]:
    """Offload np_ estimation tasks; average the results (paper Fig 6).

    Returns ``(pi, session)`` — the session carries cost/records/latency
    accounting for the run.
    """
    with session_scope(session, dispatcher) as sess:
        per = n // np_
        estimate = sess.function(lambda seed: pi_estimate(per, seed),
                                 name="pi_estimate", memory_mb=512)
        vals = [float(v) for v in estimate.map(range(np_))]
    return sum(vals) / len(vals), sess
