"""N-Queens with bit-pattern backtracking + prefix-task decomposition
(paper §5.2, Figs 12/13).

Board state is three bitmasks (cols, left/right diagonals) [Richards'97];
prefix tasks of length p fix the first p queens, breaking the search into
independent subtrees [Kise'04] — the serverless task unit.  The counter is
an iterative bitmask DFS inside ``lax.while_loop`` so the task itself is a
jax-traceable (AOT-deployable) function, and tasks are *heterogeneous* —
the property the paper uses to show pay-per-use beats worker-count scaling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..cloud import Session, session_scope
from ..dispatch import Dispatcher


def count_completions(n: int, ld: int, rd: int, col: int) -> int:
    """Count solutions from a partial state (bitmask DFS, jax-traceable)."""
    full = (1 << n) - 1
    max_depth = n + 1

    def cond(s):
        return s[1] >= 0

    def body(s):
        count, depth, lds, rds, cols, avails = s
        avail = avails[depth]

        def pop(_):
            return count, depth - 1, lds, rds, cols, avails

        def expand(_):
            bit = avail & (-avail)
            avails2 = avails.at[depth].set(avail & ~bit)
            ncol = cols[depth] | bit
            nld = ((lds[depth] | bit) << 1) & full
            nrd = (rds[depth] | bit) >> 1

            def solved(_):
                return count + 1, depth, lds, rds, cols, avails2

            def push(_):
                navail = full & ~(ncol | nld | nrd)
                d2 = depth + 1
                return (count, d2,
                        lds.at[d2].set(nld), rds.at[d2].set(nrd),
                        cols.at[d2].set(ncol), avails2.at[d2].set(navail))

            return jax.lax.cond(ncol == full, solved, push, None)

        return jax.lax.cond(avail == 0, pop, expand, None)

    z = jnp.zeros((max_depth,), jnp.int32)
    avail0 = full & ~(col | ld | rd)
    init = (jnp.int32(0), jnp.int32(0),
            z.at[0].set(ld), z.at[0].set(rd), z.at[0].set(col),
            z.at[0].set(avail0))
    out = jax.lax.while_loop(cond, body, init)
    return out[0]


def prefixes(n: int, p: int) -> list[tuple[int, int, int]]:
    """All valid (ld, rd, col) states after placing p queens (host-side)."""
    full = (1 << n) - 1
    out = []

    def rec(depth, ld, rd, col):
        if depth == p:
            out.append((ld, rd, col))
            return
        avail = full & ~(ld | rd | col)
        while avail:
            bit = avail & (-avail)
            avail &= ~bit
            rec(depth + 1, ((ld | bit) << 1) & full, (rd | bit) >> 1,
                col | bit)

    rec(0, 0, 0, 0)
    return out


def solve_serial(n: int) -> int:
    return int(count_completions(n, 0, 0, 0))


def solve_serverless(n: int, p: int,
                     dispatcher: Dispatcher | None = None,
                     session: Session | None = None):
    """Offload one task per prefix; sum the counts (paper Figs 12/13).

    The subtree counts are summed as tasks *complete* (streaming
    fork-join) — the reduction is order-independent, so nothing waits on
    the heterogeneous stragglers the paper highlights.
    """
    with session_scope(session, dispatcher) as sess:
        tasks = prefixes(n, p)
        count = sess.function(
            lambda ld, rd, col: count_completions(n, ld, rd, col),
            name=f"nqueens_{n}", memory_mb=2048)  # paper: 2 GiB for N-Queens
        total = sum(int(c) for c in count.map_unordered(
            [(jnp.int32(ld), jnp.int32(rd), jnp.int32(col))
             for ld, rd, col in tasks]))
    return total, len(tasks), sess


# ground truth for tests
KNOWN = {4: 2, 5: 10, 6: 4, 7: 40, 8: 92, 9: 352, 10: 724, 11: 2680,
         12: 14200, 13: 73712}
