"""Deployment manifest (paper §3.3, §4.2 "Linking").

Cppless's compiler emits a manifest describing every alternative entry point
(function id, resource metadata); ``cppless-ld`` merges manifests and the
deployment tool drives cloud creation from it.  Redeploys happen only when a
function's id changes.

Here the manifest is a JSON document persisted next to the artifact store and
consulted by ``Deployment.deploy`` for change detection.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any

from .config import FunctionConfig


@dataclass
class ManifestEntry:
    name: str                    # mangled stable name (the cloud function id)
    human_name: str
    kind: str                    # aot_xla | generic_worker
    config: FunctionConfig
    in_avals: list[str] = field(default_factory=list)
    out_avals: list[str] = field(default_factory=list)
    created_at: float = 0.0
    artifact: str | None = None  # artifact-store key
    # code-shipping artifact (core.codeship.freeze_function): lets a fresh
    # worker process rebuild the bridge from the manifest alone — the
    # separately-deployed entry point of the `processes`/`http` transports.
    code: dict | None = None

    def to_json(self) -> dict[str, Any]:
        d = dict(self.__dict__)
        d["config"] = self.config.to_json()
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ManifestEntry":
        d = dict(d)
        d["config"] = FunctionConfig.from_json(d["config"])
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


class Manifest:
    def __init__(self, path: str | None = None):
        self.path = path
        self.entries: dict[str, ManifestEntry] = {}
        if path and os.path.exists(path):
            self.load(path)

    def __contains__(self, name: str) -> bool:
        return name in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def add(self, entry: ManifestEntry) -> None:
        entry.created_at = entry.created_at or time.time()
        self.entries[entry.name] = entry
        if self.path:
            self.save(self.path)

    def get(self, name: str) -> ManifestEntry:
        return self.entries[name]

    def save(self, path: str) -> None:
        doc = {"version": 1,
               "functions": {n: e.to_json() for n, e in self.entries.items()}}
        # tmp name is unique per writer: concurrent saves (async serving
        # submits deploy from executor threads) must not race on one tmp
        # file — last replace wins, every replace finds its source
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: a crash never corrupts the manifest

    def load(self, path: str) -> None:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != 1:
            raise ValueError("unsupported manifest version")
        self.entries = {
            n: ManifestEntry.from_json(e) for n, e in doc["functions"].items()
        }
