from .codeship import CodeShipError, freeze_function, thaw_function
from .config import DEFAULT_CONFIG, FunctionConfig
from .function import (RemoteFunction, data_captures, rebind,
                       reflect_captures, remote)
from .naming import mangle, stable_name
from .bridge import Bridge
from .deploy import DeployedFunction, Deployment
from .manifest import Manifest, ManifestEntry

__all__ = [
    "FunctionConfig", "DEFAULT_CONFIG", "RemoteFunction", "remote",
    "reflect_captures", "rebind", "data_captures", "stable_name", "mangle",
    "Bridge", "Deployment", "DeployedFunction", "Manifest", "ManifestEntry",
    "CodeShipError", "freeze_function", "thaw_function",
]
