"""Bridge classes — the alternative entry points (paper §3.1–§3.2, Fig 4).

A Cppless bridge connects a user function object to a separately-compiled
entry point: the cloud side deserializes the payload, reconstructs the
function object, runs it, and serializes the result.  Here the "separate
compilation path" is JAX AOT (``jit(...).lower(avals).compile()``) against the
*target* device topology, and ``entry(payload: bytes) -> bytes`` is the
executable surface a worker sandbox sees — nothing else crosses the wire.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from ..serialization import deserialize, serialize
from .config import FunctionConfig
from .function import RemoteFunction, rebind, reflect_captures


@dataclass
class EntryStats:
    """Per-invocation server-side accounting (drives GB-s billing)."""
    deserialize_s: float = 0.0
    compute_s: float = 0.0
    serialize_s: float = 0.0

    @property
    def total_s(self) -> float:
        return self.deserialize_s + self.compute_s + self.serialize_s


@dataclass
class Bridge:
    """A deployed alternative entry point."""
    name: str
    config: FunctionConfig
    # executor(args, kwargs, captures) -> result; already specialized/compiled.
    executor: Callable[..., Any]
    kind: str = "aot_xla"  # or "generic_worker" for non-traceable tasks
    # Last-completed stats, best-effort observability only: one bridge may be
    # entered concurrently (warm sandboxes of the same function), so per-
    # invocation accounting must use the stats *returned* by ``entry``.
    last_stats: EntryStats = field(default_factory=EntryStats)

    def pack(self, args: tuple, kwargs: dict, captures: dict) -> bytes:
        return serialize((args, kwargs, captures), format=self.config.serializer)

    def entry(self, payload: bytes) -> tuple[bytes, EntryStats]:
        """The remote main(): bytes in, (bytes, stats) out (paper Fig 4).

        Stats are returned (not only stored) so concurrent invocations of
        the same deployed function cannot corrupt each other's accounting.
        """
        stats = EntryStats()
        t0 = time.perf_counter()
        args, kwargs, captures = deserialize(payload)
        t1 = time.perf_counter()
        out = self.executor(args, kwargs, captures)
        out = jax.block_until_ready(out)
        t2 = time.perf_counter()
        blob = serialize(out, format=self.config.serializer)
        t3 = time.perf_counter()
        stats.deserialize_s, stats.compute_s, stats.serialize_s = (
            t1 - t0, t2 - t1, t3 - t2)
        self.last_stats = stats
        return blob, stats

    def unpack_result(self, blob: bytes) -> Any:
        return deserialize(blob, format=self.config.serializer)


_STATIC_TYPES = (bool, int, float, str, bytes)


def _is_static_capture(v: Any) -> bool:
    """Compile-time constant vs. dynamic payload input.

    Python scalars and any *hashable* structured value (frozen dataclasses
    like ``ModelConfig``, tuples of scalars) are template-parameter-like:
    their values determine shapes/control flow, so they bake into the
    traced jaxpr.  Arrays (jax/numpy, including numpy scalars) stay
    dynamic — they are the data the payload exists to carry.
    """
    if isinstance(v, _STATIC_TYPES):
        return True
    import numpy as np
    if isinstance(v, (np.ndarray, np.generic)) or \
            type(v).__module__.startswith("jax"):
        return False
    try:
        hash(v)
    except TypeError:
        return False
    return True


def make_executor_aot(rf: RemoteFunction, args: tuple, kwargs: dict,
                      captures: dict) -> Callable:
    """AOT path: lower+compile once against abstract payloads.

    The compile happens at *deploy* time (ahead of any invocation) — the
    defining property of Cppless's alternative entry points vs. runtime
    code shipping (Lithops).

    Python-scalar and hashable structured captures (frozen dataclasses
    like ``ModelConfig``) are **compile-time constants** (the analogue of
    Cppless's template parameters): they are rebound into the closure
    BEFORE tracing, so `range(n)`/`arange(tile)`/`build_model(cfg)`-style
    uses stay static.  Leaving them as traced inputs would raise on any
    shape-determining use and silently demote the function to the eager
    generic worker — measured ~250x slower on the raytracer tiles, ~60x
    on the LM serve task.  Array captures remain dynamic payload inputs.
    Changed static values change the traced jaxpr, hence the stable name,
    hence deploy a new entry point — the correct Cppless semantics.
    """
    # example payloads may carry ArtifactRefs in place of large constants;
    # specialization needs the real arrays (shapes drive the lowering)
    from ..serialization import resolve_artifacts
    args = resolve_artifacts(args)
    kwargs = resolve_artifacts(kwargs)
    captures = resolve_artifacts(captures)

    static = {k: v for k, v in captures.items() if _is_static_capture(v)}
    dynamic = {k: v for k, v in captures.items() if k not in static}
    base_fn = rebind(rf.fn, static) if static else rf.fn

    def with_payload(args_, kwargs_, dyn_):
        fn = rebind(base_fn, dyn_) if dyn_ else base_fn
        return fn(*args_, **kwargs_)

    lowered = jax.jit(with_payload).lower(args, kwargs, dynamic)
    compiled = lowered.compile()
    dyn_keys = tuple(dynamic)

    def executor(args_, kwargs_, captures_):
        dyn = {k: captures_[k] for k in dyn_keys}
        return compiled(args_, kwargs_, dyn)

    executor.lowered = lowered
    executor.compiled = compiled
    return executor


def make_executor_generic(rf: RemoteFunction) -> Callable:
    """Generic-worker path for non-jax tasks (numpy / pure python).

    Mirrors the Lithops model the paper contrasts with: the worker rebinds
    captures and runs the python callable directly.
    """
    def executor(args_, kwargs_, captures_):
        fn = rebind(rf.fn, captures_) if captures_ else rf.fn
        return fn(*args_, **kwargs_)

    return executor
