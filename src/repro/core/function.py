"""Remote function objects and capture reflection.

Cppless models serverless functions as *function objects* (usually lambdas)
whose captured state is serialized and whose type names the deployed cloud
function (paper §3.2).  Two compiler extensions make that possible in C++:
capture reflection and unique stable naming.

Python gives us both without a compiler fork, and the analogy is exact:

* **capture reflection** — ``fn.__code__.co_freevars`` + ``fn.__closure__``
  expose the (otherwise unnamed) capture cells of a closure, like the
  ``capture<I>()`` accessors Cppless adds to clang; ``rebind()`` reconstructs
  the closure remotely from deserialized capture values.
* **unique stable naming** — the traced jaxpr (or, for non-traceable tasks,
  the marshalled code object) is content-addressed; see ``naming.py``.

Single-source property: a ``RemoteFunction`` is still a plain callable — the
same object runs locally (``rf(*args)``), in local threads, or remotely via a
dispatcher, exactly like the paper's Fig 1 comparison.
"""
from __future__ import annotations

import hashlib
import marshal
import types
from typing import Any, Callable

from .config import DEFAULT_CONFIG, FunctionConfig
from . import naming


def reflect_captures(fn: Callable) -> dict[str, Any]:
    """Read the closure's capture cells: {freevar name: captured value}."""
    names = fn.__code__.co_freevars
    cells = fn.__closure__ or ()
    if len(names) != len(cells):  # pragma: no cover
        raise ValueError("closure cells do not match freevars")
    return {n: c.cell_contents for n, c in zip(names, cells)}


def rebind(fn: Callable, captures: dict[str, Any]) -> Callable:
    """Reconstruct ``fn`` with its capture cells replaced by ``captures``.

    This is the remote half of capture reflection: the entry point receives
    deserialized capture values and splices them back into the closure.
    Names absent from ``captures`` keep their original cells — code captures
    (helper callables) travel with the deployed artifact, not the payload,
    exactly as Cppless links static dependencies into the entry-point binary.
    """
    names = fn.__code__.co_freevars
    orig = fn.__closure__ or ()
    cells = tuple(
        types.CellType(captures[n]) if n in captures else orig[i]
        for i, n in enumerate(names)
    )
    return types.FunctionType(
        fn.__code__, fn.__globals__, fn.__name__, fn.__defaults__, cells
    )


def is_code_capture(v: Any) -> bool:
    """Does this capture travel with the deployed *artifact* (not payloads)?

    Mirrors ``freeze_function``'s capture branch exactly: modules, python
    functions (``__code__`` present), and importable callables (classes,
    module-level singletons) are frozen into the code artifact; everything
    else — including callable instances with no ``__code__`` and no
    importable ref — is a data capture whose value ships per-invocation.
    """
    if isinstance(v, types.ModuleType):
        return True
    if not callable(v):
        return False
    if getattr(v, "__code__", None) is not None:
        return True
    from .codeship import _importable
    return _importable(v)


def data_captures(fn: Callable) -> dict[str, Any]:
    """The payload-travelling capture subset (everything not shipped as code)."""
    return {
        k: v for k, v in reflect_captures(fn).items() if not is_code_capture(v)
    }


def code_fingerprint(fn: Callable) -> str:
    """Fallback identity for non-jax-traceable tasks: hash the code object.

    Marshal of ``co_code`` + consts + freevar names is stable across processes
    for the same source — the role Itanium mangling plays in Cppless.
    """
    code = fn.__code__
    payload = marshal.dumps(
        (code.co_code, code.co_consts, code.co_names, code.co_freevars,
         code.co_varnames, code.co_argcount)
    )
    return hashlib.sha256(payload).hexdigest()


class RemoteFunction:
    """A function earmarked for serverless offload (the bridge-class handle).

    ``fn`` may take explicit arguments and/or close over captured values.
    The payload shipped per invocation is ``(args, kwargs, captures)``.
    """

    def __init__(self, fn: Callable, *, name: str | None = None,
                 config: FunctionConfig = DEFAULT_CONFIG,
                 jax_traceable: bool = True):
        self.fn = fn
        self.human_name = name or getattr(fn, "__name__", "lambda")
        self.config = config
        self.jax_traceable = jax_traceable

    # -- single-source: local call path is untouched ------------------------
    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)

    # -- identity ------------------------------------------------------------
    def fingerprint(self, *abstract_args, **abstract_kwargs) -> str:
        """Content identity. Jaxpr-based when traceable, bytecode otherwise."""
        if self.jax_traceable:
            try:
                # artifact references stand in for large constants in
                # payloads; identity must come from the *values* (their
                # shapes shape the jaxpr), so resolve before tracing
                from ..serialization import resolve_artifacts
                abstract_args = resolve_artifacts(abstract_args)
                abstract_kwargs = resolve_artifacts(abstract_kwargs)
                return naming.jaxpr_fingerprint(
                    self.fn, *abstract_args, **abstract_kwargs
                )
            except Exception:
                pass  # fall through to bytecode identity
        base = code_fingerprint(self.fn)
        caps = reflect_captures(self.fn)
        # Captured *callables* contribute code identity (transitive deps),
        # mirroring how Cppless links the function's static dependencies.
        h = hashlib.sha256(base.encode())
        for k in sorted(caps):
            v = caps[k]
            if callable(v) and hasattr(v, "__code__"):
                h.update(k.encode())
                h.update(code_fingerprint(v).encode())
        return h.hexdigest()

    def stable_name(self, *abstract_args, salt: str = "", **abstract_kwargs) -> str:
        fp = self.fingerprint(*abstract_args, **abstract_kwargs)
        return naming.mangle(self.human_name, fp, salt=salt)

    def __repr__(self):
        return f"RemoteFunction({self.human_name!r}, config={self.config})"


def remote(fn: Callable | None = None, *, name: str | None = None,
           config: FunctionConfig = DEFAULT_CONFIG,
           jax_traceable: bool = True):
    """Decorator form: ``@remote`` / ``@remote(config=cfg.with_memory(512))``."""
    def wrap(f):
        return RemoteFunction(f, name=name, config=config,
                              jax_traceable=jax_traceable)
    return wrap(fn) if fn is not None else wrap
