"""Deployment: turn RemoteFunctions into invocable cloud artifacts.

The Cppless flow (paper Fig 5): compile alternative entry points → emit
manifest → deployment tool creates/updates cloud functions, *only if a code
change is detected*.  Our flow: specialize the function on abstract payloads,
AOT lower+compile (the separate compilation path), register the Bridge under
its content-addressed stable name, and record it in the manifest.  A repeat
deploy of an unchanged function is a cache hit — no recompilation.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax

from .bridge import Bridge, make_executor_aot, make_executor_generic
from .codeship import freeze_function
from .config import DEFAULT_CONFIG, FunctionConfig
from .function import RemoteFunction, data_captures
from .manifest import Manifest, ManifestEntry


@dataclass
class DeployedFunction:
    name: str
    bridge: Bridge
    remote_fn: RemoteFunction
    entry_args: tuple          # example (args, kwargs, captures) for shape ref
    compile_s: float = 0.0

    @property
    def config(self) -> FunctionConfig:
        return self.bridge.config


class Deployment:
    """Artifact store + manifest; the `aws_lambda_serverless_target` analogue."""

    def __init__(self, manifest_path: str | None = None):
        self.manifest = Manifest(manifest_path)
        self._functions: dict[str, DeployedFunction] = {}
        self.compile_count = 0   # observability: redeploy-on-change works
        self.cache_hits = 0

    # ------------------------------------------------------------------ api
    def deploy(self, fn: Callable | RemoteFunction, *example_args: Any,
               config: FunctionConfig | None = None,
               **example_kwargs: Any) -> DeployedFunction:
        rf = fn if isinstance(fn, RemoteFunction) else RemoteFunction(fn)
        cfg = config or rf.config
        captures = data_captures(rf.fn)
        payload = (example_args, example_kwargs, captures)

        # Artifact/billing config is part of the function's type (Cppless:
        # compile-time template metadata), so it salts the deployed name:
        # same code with different memory/serializer is a *different* cloud
        # function — this is what makes `.options()` overrides take effect.
        # Pure client policy (timeout, retries, hedging) travels with each
        # invocation instead, so overriding it never forces a redeploy.
        cfg_d = cfg.to_json()
        salt = json.dumps({k: cfg_d[k] for k in
                           ("memory_mb", "ephemeral_mb", "serializer")},
                          sort_keys=True)
        name = rf.stable_name(*example_args, salt=salt, **example_kwargs)
        if name in self._functions:
            self.cache_hits += 1          # unchanged code → no redeploy
            return self._functions[name]

        t0 = time.perf_counter()
        kind = "generic_worker"
        if rf.jax_traceable:
            try:
                executor = make_executor_aot(rf, *payload)
                kind = "aot_xla"
            except Exception:
                executor = make_executor_generic(rf)
        else:
            executor = make_executor_generic(rf)
        compile_s = time.perf_counter() - t0
        self.compile_count += 1

        bridge = Bridge(name=name, config=cfg, executor=executor, kind=kind)
        deployed = DeployedFunction(name=name, bridge=bridge, remote_fn=rf,
                                    entry_args=payload, compile_s=compile_s)
        self._functions[name] = deployed

        in_avals, out_avals = self._aval_strings(rf, payload, kind, executor)
        try:
            code = freeze_function(rf.fn)
        except Exception:
            code = None        # local-only function: in-process backends fine
        self.manifest.add(ManifestEntry(
            name=name, human_name=rf.human_name, kind=kind, config=cfg,
            in_avals=in_avals, out_avals=out_avals, artifact=name, code=code))
        return deployed

    def get(self, name: str) -> DeployedFunction:
        return self._functions[name]

    def __contains__(self, name: str) -> bool:
        return name in self._functions

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _aval_strings(rf, payload, kind, executor):
        if kind != "aot_xla":
            return [], []
        try:
            lowered = executor.lowered
            in_avals = [str(a) for a in jax.tree_util.tree_leaves(
                jax.eval_shape(lambda *p: p, *payload))]
            out_info = lowered.out_info
            out_avals = [f"{v.shape}:{v.dtype}"
                         for v in jax.tree_util.tree_leaves(out_info)]
            return in_avals, out_avals
        except Exception:
            return [], []
