"""Deployment: turn RemoteFunctions into invocable cloud artifacts.

The Cppless flow (paper Fig 5): compile alternative entry points → emit
manifest → deployment tool creates/updates cloud functions, *only if a code
change is detected*.  Our flow: specialize the function on abstract payloads,
AOT lower+compile (the separate compilation path), register the Bridge under
its content-addressed stable name, and record it in the manifest.  A repeat
deploy of an unchanged function is a cache hit — no recompilation.
"""
from __future__ import annotations

import json
import threading
import time
import warnings
import weakref
from dataclasses import dataclass
from typing import Any, Callable

import jax

from .bridge import Bridge, make_executor_aot, make_executor_generic
from .codeship import freeze_function
from .config import DEFAULT_CONFIG, FunctionConfig
from .function import RemoteFunction, data_captures
from .manifest import Manifest, ManifestEntry


@dataclass
class DeployedFunction:
    name: str
    bridge: Bridge
    remote_fn: RemoteFunction
    entry_args: tuple          # example (args, kwargs, captures) for shape ref
    compile_s: float = 0.0
    # Deploy-time shippability diagnostics (repro.analysis).  A tuple —
    # possibly empty — once analysis ran; None if the analyzer itself
    # failed, in which case the failure-hint path re-analyzes on demand.
    diagnostics: tuple | None = ()

    @property
    def config(self) -> FunctionConfig:
        return self.bridge.config


class Deployment:
    """Artifact store + manifest; the `aws_lambda_serverless_target` analogue."""

    def __init__(self, manifest_path: str | None = None):
        self.manifest = Manifest(manifest_path)
        self._functions: dict[str, DeployedFunction] = {}
        self.compile_count = 0   # observability: redeploy-on-change works
        self.cache_hits = 0
        # async serving submits from executor threads: concurrent deploys
        # of the same function must compile once, not race the cache
        self._lock = threading.RLock()
        # Shippability analysis (repro.analysis) runs on every cache-miss
        # deploy.  strict_analysis upgrades error-severity findings to an
        # AnalysisError *before* anything ships; the dispatcher flips
        # analysis_cross_process off for in-process backends so RF101
        # (fresh-globals NameError) reports as info, not error.
        self.strict_analysis = False
        self.analysis_cross_process = True
        self._warned: set[str] = set()
        # dispatch-path fast cache: content identity (stable_name) traces
        # the function, which costs ~100 ms for a real serve task — per
        # SUBMIT.  Repeat dispatches hit this shape/value key instead and
        # never re-trace; anything the AOT path would bake differently
        # (arg shapes/dtypes, scalar values, static captures, billing
        # config) is part of the key, so a fast hit is always the same
        # entry point the slow path would have chosen.
        self._fast_cache: dict[Any, DeployedFunction] = {}

    # ------------------------------------------------------------------ api
    def deploy(self, fn: Callable | RemoteFunction, *example_args: Any,
               config: FunctionConfig | None = None,
               **example_kwargs: Any) -> DeployedFunction:
        rf = fn if isinstance(fn, RemoteFunction) else RemoteFunction(fn)
        cfg = config or rf.config
        captures = data_captures(rf.fn)
        payload = (example_args, example_kwargs, captures)

        key = self._fast_key(rf, cfg, example_args, example_kwargs)
        if key is not None:
            with self._lock:
                hit = self._fast_cache.get(key)
                # the key carries id(fn): guard against a dead function
                # object's id being reused by different code
                if hit is not None and hit[0]() is rf.fn:
                    self.cache_hits += 1
                    return hit[1]

        # Artifact/billing config is part of the function's type (Cppless:
        # compile-time template metadata), so it salts the deployed name:
        # same code with different memory/serializer is a *different* cloud
        # function — this is what makes `.options()` overrides take effect.
        # Pure client policy (timeout, retries, hedging) travels with each
        # invocation instead, so overriding it never forces a redeploy.
        cfg_d = cfg.to_json()
        salt = json.dumps({k: cfg_d[k] for k in
                           ("memory_mb", "ephemeral_mb", "serializer")},
                          sort_keys=True)
        name = rf.stable_name(*example_args, salt=salt, **example_kwargs)
        with self._lock:
            deployed = self._deploy_locked(rf, cfg, payload, name)
            if key is not None:
                # bounded: scalar arg values are part of the key, so an
                # argument sweep would otherwise grow this forever
                while len(self._fast_cache) >= 4096:
                    self._fast_cache.pop(next(iter(self._fast_cache)))
                self._fast_cache[key] = (weakref.ref(rf.fn), deployed)
            return deployed

    def _fast_key(self, rf: RemoteFunction, cfg: FunctionConfig,
                  args: tuple, kwargs: dict):
        """Hashable dispatch-cache key, or ``None`` to use the slow path.

        Components mirror exactly what changes the deployed entry point:
        the function object, artifact/billing config (the name salt), arg
        *shapes* (arrays trace shape-generically) and scalar arg values,
        plus non-callable capture values — static captures bake into the
        jaxpr, array captures contribute shape.  ``ArtifactRef`` leaves key
        by content hash, so a repeat params pointer never loads the value.
        """
        try:
            import jax

            from ..serialization.artifacts import ArtifactRef

            weakref.ref(rf.fn)     # non-weakrefable callable → slow path

            def leaf_sig(v: Any):
                if isinstance(v, ArtifactRef):
                    return ("artifact", v.sha)
                if hasattr(v, "shape") and hasattr(v, "dtype"):
                    return ("array", tuple(v.shape), str(v.dtype))
                return ("value", type(v).__name__, v)

            leaves, treedef = jax.tree_util.tree_flatten(
                (args, kwargs), is_leaf=lambda x: isinstance(x, ArtifactRef))
            caps = (data_captures(rf.fn) if rf.fn.__closure__ else {})
            key = (id(rf.fn), rf.human_name, rf.jax_traceable,
                   cfg.memory_mb, cfg.ephemeral_mb, cfg.serializer,
                   treedef, tuple(leaf_sig(v) for v in leaves),
                   tuple((k, leaf_sig(v)) for k, v in sorted(caps.items())))
            hash(key)                  # unhashable component → slow path
            return key
        except Exception:
            return None

    def _deploy_locked(self, rf: RemoteFunction, cfg: FunctionConfig,
                       payload: tuple, name: str) -> DeployedFunction:
        if name in self._functions:
            self.cache_hits += 1          # unchanged code → no redeploy
            return self._functions[name]

        # Compile-time validation before anything ships (Cppless: the LLVM
        # extension rejects un-extractable lambdas at build time).  Strict
        # mode raises here — before AOT compile, before the manifest entry.
        diagnostics = self._analyze(rf, cfg, name)

        t0 = time.perf_counter()
        kind = "generic_worker"
        if rf.jax_traceable:
            try:
                executor = make_executor_aot(rf, *payload)
                kind = "aot_xla"
            except Exception:
                executor = make_executor_generic(rf)
        else:
            executor = make_executor_generic(rf)
        compile_s = time.perf_counter() - t0
        self.compile_count += 1

        bridge = Bridge(name=name, config=cfg, executor=executor, kind=kind)
        deployed = DeployedFunction(name=name, bridge=bridge, remote_fn=rf,
                                    entry_args=payload, compile_s=compile_s,
                                    diagnostics=diagnostics)
        self._functions[name] = deployed

        in_avals, out_avals = self._aval_strings(rf, payload, kind, executor)
        try:
            code = freeze_function(rf.fn)
        except Exception:
            code = None        # local-only function: in-process backends fine
        self.manifest.add(ManifestEntry(
            name=name, human_name=rf.human_name, kind=kind, config=cfg,
            in_avals=in_avals, out_avals=out_avals, artifact=name, code=code))
        return deployed

    def _analyze(self, rf: RemoteFunction, cfg: FunctionConfig,
                 name: str) -> tuple | None:
        """Run the shippability pass; gate on strictness; warn once.

        Returns the diagnostic tuple stored on the DeployedFunction (used
        later by the transport failure-hint path), or ``None`` if the
        analyzer itself crashed — analysis must never take down a deploy
        except through its own strict-mode contract.
        """
        from ..analysis import (AnalysisError, ShippabilityWarning,
                                analyze_function)
        try:
            diags = tuple(analyze_function(
                rf.fn, name=rf.human_name,
                cross_process=self.analysis_cross_process))
        except AnalysisError:
            raise
        except Exception:
            return None
        errors = [d for d in diags if d.severity == "error"]
        if errors and (cfg.strict or self.strict_analysis):
            raise AnalysisError(rf.human_name, errors)
        loud = [d for d in diags if d.severity in ("error", "warning")]
        if loud and name not in self._warned:
            self._warned.add(name)
            lines = "\n".join("  " + d.format() for d in loud)
            warnings.warn(
                f"shippability analysis of {rf.human_name!r} found "
                f"{len(loud)} issue(s):\n{lines}",
                ShippabilityWarning, stacklevel=4)
        return diags

    def get(self, name: str) -> DeployedFunction:
        return self._functions[name]

    def __contains__(self, name: str) -> bool:
        return name in self._functions

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _aval_strings(rf, payload, kind, executor):
        if kind != "aot_xla":
            return [], []
        try:
            lowered = executor.lowered
            in_avals = [str(a) for a in jax.tree_util.tree_leaves(
                jax.eval_shape(lambda *p: p, *payload))]
            out_info = lowered.out_info
            out_avals = [f"{v.shape}:{v.dtype}"
                         for v in jax.tree_util.tree_leaves(out_info)]
            return in_avals, out_avals
        except Exception:
            return [], []
