"""Unique stable identification of remote functions.

Cppless (paper §4.3) backs function↔entry-point identification with
``__builtin_unique_stable_name`` — a *modified Itanium mangling* that strips
inlined namespaces so the identifier is stable across standard-library
implementations.

The JAX analogue: a function's "type" is its **jaxpr** (the traced program) +
the abstract values it was specialized on.  We canonicalize the jaxpr text so
the id is stable across processes and incidental differences (variable ids,
object addresses, source paths), then content-address it with SHA-256.  Two
call sites that trace to the same program get the same deployed function —
exactly the dedup behavior of Cppless's type-keyed entry points — and any
code change flips the id, which is what drives redeploy-on-change.
"""
from __future__ import annotations

import hashlib
import re

import jax

# Matches jaxpr variable tokens (a..z, aa..) and memory addresses.
_ADDR_RE = re.compile(r"0x[0-9a-fA-F]+")
_WS_RE = re.compile(r"\s+")
# Source-location / name-stack noise that may embed absolute paths.
_PATHY_RE = re.compile(r"(/[\w.\-/]+\.py[:0-9]*)")


def canonicalize_jaxpr_text(text: str) -> str:
    """Normalize a jaxpr pretty-print for hashing.

    The analogue of stripping inlined namespaces from the Itanium mangling:
    remove process-incidental detail (addresses, absolute paths, whitespace
    layout) while keeping the full program structure, dtypes and shapes.
    """
    text = _ADDR_RE.sub("0xADDR", text)
    text = _PATHY_RE.sub("<src>", text)
    text = _WS_RE.sub(" ", text).strip()
    return text


def jaxpr_fingerprint(fn, *abstract_args, static_argnums=(), **abstract_kwargs) -> str:
    """SHA-256 over the canonicalized closed jaxpr of ``fn`` at these avals."""
    closed = jax.make_jaxpr(fn, static_argnums=static_argnums)(
        *abstract_args, **abstract_kwargs
    )
    canon = canonicalize_jaxpr_text(str(closed))
    avals = ",".join(
        f"{a.shape}:{a.dtype}" for a in closed.in_avals
    )
    h = hashlib.sha256()
    h.update(canon.encode())
    h.update(b"|avals|")
    h.update(avals.encode())
    return h.hexdigest()


def mangle(human_name: str, fingerprint: str, salt: str = "") -> str:
    """Produce the deployable function name.

    Shaped after the Itanium scheme Cppless modifies: a fixed prefix, the
    length-prefixed human name, and the content hash.  Cloud function names
    must be short and [A-Za-z0-9_-], which this guarantees.
    """
    clean = re.sub(r"[^A-Za-z0-9_]", "_", human_name)[:48]
    if salt:
        fingerprint = hashlib.sha256(
            (fingerprint + "|" + salt).encode()
        ).hexdigest()
    return f"_ZRF{len(clean)}{clean}I{fingerprint[:16]}E"


def stable_name(fn, *abstract_args, human_name: str | None = None,
                salt: str = "", **abstract_kwargs) -> str:
    """End-to-end: trace → canonicalize → hash → mangle."""
    fp = jaxpr_fingerprint(fn, *abstract_args, **abstract_kwargs)
    name = human_name or getattr(fn, "__name__", "lambda")
    # <locals> in qualnames is incidental (the "inline namespace" analogue).
    name = name.replace("<locals>", "").replace("<lambda>", "lambda")
    return mangle(name, fp, salt=salt)
