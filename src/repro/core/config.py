"""Per-function resource configuration (paper Fig 6, lines 11–14).

Cppless lets users attach compile-time metadata to a function::

    using config = lambda::config<
        cppless::lambda::with_memory<512>,
        cppless::lambda::with_ephemeral_storage<64>>;

Here the same knobs are a frozen dataclass carried in the deployment manifest
and honored by the dispatcher's scheduler and GB-seconds cost model.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FunctionConfig:
    memory_mb: int = 1024          # AWS Lambda default in the paper's evaluation
    ephemeral_mb: int = 512
    timeout_s: float = 900.0
    max_retries: int = 2           # serverless contract: idempotent → retry
    hedge_after_quantile: float | None = None  # straggler backup (beyond paper)
    serializer: str = "binary"     # binary | binary_json | structured_json
    # Worker pinning for stateful serving (ISSUE 5): invocations sharing an
    # affinity key land on the same worker slot, so a resident cache arena
    # is reachable across calls.  Pure dispatch policy — it travels with
    # each Invocation and never salts the deployed name (same entry point,
    # different routing).  None = any worker (the stateless default).
    affinity: int | None = None
    # Per-function strict shippability: error-severity analyzer findings
    # reject the deploy with AnalysisError instead of warning.  Client
    # policy like timeout/retries — never salts the deployed name.
    strict: bool = False
    # Per-request deadline budget (seconds from dispatch).  The dispatcher
    # stamps an absolute epoch deadline on each invocation; it rides the
    # wire envelope so workers reject already-expired work instead of
    # computing it, and the retry path refuses to resubmit past it.
    # None = no deadline (timeout_s still bounds the client-side wait).
    deadline_s: float | None = None

    def with_memory(self, mb: int) -> "FunctionConfig":
        return dataclasses.replace(self, memory_mb=mb)

    def with_ephemeral_storage(self, mb: int) -> "FunctionConfig":
        return dataclasses.replace(self, ephemeral_mb=mb)

    def with_timeout(self, s: float) -> "FunctionConfig":
        return dataclasses.replace(self, timeout_s=s)

    def with_serializer(self, fmt: str) -> "FunctionConfig":
        return dataclasses.replace(self, serializer=fmt)

    def with_hedging(self, quantile: float = 0.95) -> "FunctionConfig":
        return dataclasses.replace(self, hedge_after_quantile=quantile)

    def with_strict(self, strict: bool = True) -> "FunctionConfig":
        return dataclasses.replace(self, strict=strict)

    def with_deadline(self, s: float | None) -> "FunctionConfig":
        return dataclasses.replace(self, deadline_s=s)

    @property
    def memory_gb(self) -> float:
        return self.memory_mb / 1024.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "FunctionConfig":
        return cls(**d)


DEFAULT_CONFIG = FunctionConfig()
