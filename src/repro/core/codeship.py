"""Code shipping — make deployed functions reconstructable in a fresh process.

Cppless deploys a *separately compiled* entry-point binary; the worker never
sees the client's address space (paper §3.3).  The Python analogue: a
deployed function must be rebuildable from the **manifest alone**, in a
process that shares nothing with the client but the installed package tree.
``freeze_function`` captures a JSON-able description of a callable;
``thaw_function`` rebuilds it on the worker side.

Two shipping modes, mirroring how Cppless links entry points:

* ``ref``  — the function is importable (module-level def in an importable
             module): ship only ``module:qualname``; the worker imports it.
             This is the "static dependency linked into the binary" case.
* ``code`` — closures / lambdas / ``__main__`` functions: ship the marshalled
             code object plus the *structure* of its closure.  Callable and
             module captures are frozen recursively (they are part of the
             artifact); data captures are left as payload slots — their
             values arrive per-invocation in the serialized payload and are
             spliced in by ``rebind`` (capture reflection, ``function.py``).

``marshal`` ties artifacts to one interpreter version — exactly the
contract of a container image built alongside the client, and the reason
the manifest is versioned.
"""
from __future__ import annotations

import base64
import builtins
import importlib
import marshal
import types
from typing import Any, Callable

from ..serialization import deserialize, serialize


class CodeShipError(RuntimeError):
    """A function cannot be frozen/thawed for out-of-process execution."""


def _importable(fn: Callable) -> bool:
    mod = getattr(fn, "__module__", None)
    qual = getattr(fn, "__qualname__", "")
    if not mod or mod == "__main__" or "<" in qual:
        return False
    try:
        obj = importlib.import_module(mod)
        for part in qual.split("."):
            obj = getattr(obj, part)
        return obj is fn
    except Exception:
        return False


def freeze_function(fn: Callable) -> dict[str, Any]:
    """A JSON-able artifact from which ``thaw_function`` rebuilds ``fn``."""
    if _importable(fn):
        return {"kind": "ref", "module": fn.__module__,
                "qualname": fn.__qualname__}
    code = getattr(fn, "__code__", None)
    if code is None:
        raise CodeShipError(f"cannot freeze non-python callable {fn!r}")
    freevars: dict[str, Any] = {}
    cells = fn.__closure__ or ()
    for name, cell in zip(code.co_freevars, cells):
        try:
            v = cell.cell_contents
        except ValueError:          # empty cell (self-reference): payload slot
            freevars[name] = None
            continue
        if isinstance(v, types.ModuleType):
            freevars[name] = {"kind": "module", "module": v.__name__}
        elif callable(v) and (getattr(v, "__code__", None) is not None
                              or _importable(v)):
            freevars[name] = freeze_function(v)
        else:
            # Data capture: value travels in payloads.  Callables with no
            # __code__ and no importable ref (callable instances, local
            # classes) land here too — they ship by value like any other
            # capture instead of exploding in recursive freezing; the
            # analyzer's RF103/RF104 rules explain the residual cases
            # where that value cannot serialize.
            freevars[name] = None
    if fn.__defaults__:
        try:
            # the payload serializer, not marshal: default values may be
            # jax/numpy arrays, and silently dropping them would make a
            # default-relying call succeed in-process but fail on a worker
            defaults = base64.b64encode(
                serialize(list(fn.__defaults__))).decode()
        except Exception as e:
            raise CodeShipError(
                f"default argument values of {fn.__name__!r} are not "
                f"wire-serializable ({e}); the function cannot ship to "
                f"out-of-process workers") from None
    else:
        defaults = None
    return {"kind": "code",
            "module": getattr(fn, "__module__", None),
            "name": fn.__name__,
            "code": base64.b64encode(marshal.dumps(code)).decode(),
            "defaults": defaults,
            "freevars": freevars}


def _thaw_globals(module: str | None) -> dict:
    """Globals for a shipped code object.

    The defining module is imported when possible (its module-level names —
    helper functions, imported libraries — are the code's static deps).
    ``__main__`` code gets fresh globals: such functions must import what
    they use inside their own body, the documented contract for script-
    defined serverless functions.
    """
    if module and module != "__main__":
        try:
            return vars(importlib.import_module(module))
        except Exception:
            pass
    return {"__builtins__": builtins}


def thaw_function(frozen: dict[str, Any] | None) -> Callable:
    """Rebuild a callable from a ``freeze_function`` artifact."""
    if not frozen:
        raise CodeShipError("manifest entry carries no code artifact "
                            "(deployed by an older client?)")
    kind = frozen.get("kind")
    if kind == "ref":
        obj: Any = importlib.import_module(frozen["module"])
        for part in frozen["qualname"].split("."):
            obj = getattr(obj, part)
        return obj
    if kind == "module":
        return importlib.import_module(frozen["module"])  # type: ignore
    if kind != "code":
        raise CodeShipError(f"unknown code artifact kind {kind!r}")
    code = marshal.loads(base64.b64decode(frozen["code"]))
    defaults = tuple(deserialize(base64.b64decode(frozen["defaults"]))) \
        if frozen.get("defaults") else None
    cells = tuple(
        types.CellType() if sub is None else types.CellType(thaw_function(sub))
        for sub in (frozen["freevars"].get(n) for n in code.co_freevars))
    return types.FunctionType(code, _thaw_globals(frozen.get("module")),
                              frozen.get("name", code.co_name),
                              defaults, cells or None)
