from .presets import PRESETS, resolve
from .rules import (AxisRules, DEFAULT_RULES, current_rules, shard,
                    tree_pspecs, tree_shardings, use_rules)

__all__ = ["PRESETS", "resolve", "AxisRules", "DEFAULT_RULES", "current_rules", "shard",
           "tree_pspecs", "tree_shardings", "use_rules"]
