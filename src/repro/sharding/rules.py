"""Logical-axis sharding with divisibility fallback.

Params and activations are annotated with *logical* axis names; a rule table
maps each logical axis to mesh axes.  A dimension that does not divide the
product of its mesh axes falls back by dropping mesh axes from the right
until it divides (ultimately unsharded) — fallbacks are recorded so the
roofline report can show where replication was forced.

Param logical axes:   layers, embed, mlp, heads, kv_heads, head_dim, vocab,
                      experts, inner, state, conv, lora, group
Activation axes:      act_batch, act_seq, act_embed, act_heads, act_mlp,
                      act_vocab, act_experts, act_cap, act_kv_seq
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default rule table: TP on the `model` axis, FSDP-style weight sharding on
# the `data` axis (ZeRO-3 analogue: XLA all-gathers at use), batch over
# (pod, data).  `None` = always replicated.
DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    # ---- params
    "layers": None,
    "embed": ("data",),          # FSDP dim on weight matrices
    "mlp": ("model",),           # Megatron TP: column/row parallel ffn
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "vocab": ("model",),
    "experts": ("model",),       # EP: 16 experts over 16-way model axis
    "inner": ("model",),         # mamba2 d_inner channels
    "state": None,
    "conv": None,
    "lora": None,
    "group": None,
    None: None,
    # ---- activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    "act_cap": None,
    "act_kv_seq": None,          # hillclimb lever: ("model",) = flash-decode SP
    "act_inner": ("model",),
    "act_state": None,
}


@dataclass
class AxisRules:
    """Rule table bound to a mesh; resolves logical specs with fallback."""
    mesh: Mesh
    rules: dict[str, tuple[str, ...] | None] = field(
        default_factory=lambda: dict(DEFAULT_RULES))
    fallbacks: list[tuple[str, int, tuple[str, ...]]] = field(
        default_factory=list)

    def replace(self, **overrides) -> "AxisRules":
        r = dict(self.rules)
        r.update(overrides)
        return AxisRules(self.mesh, r)

    def _axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]

    def resolve_dim(self, logical: str | None, dim: int,
                    used: set[str]) -> tuple[str, ...] | None:
        """Mesh axes for one dimension, with divisibility + reuse fallback."""
        cand = self.rules.get(logical)
        if not cand:
            return None
        cand = tuple(a for a in cand
                     if a in self.mesh.axis_names and a not in used)
        while cand:
            prod = 1
            for a in cand:
                prod *= self._axis_size(a)
            if dim % prod == 0 and prod > 1:
                return cand
            dropped = cand
            cand = cand[:-1]
            if cand != dropped[:-1]:  # pragma: no cover
                break
        if self.rules.get(logical):
            self.fallbacks.append((str(logical), dim,
                                   tuple(self.rules[logical] or ())))
        return None

    def spec(self, logical_axes: tuple, shape: tuple) -> P:
        """PartitionSpec for an array given its logical axes and shape."""
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set[str] = set()
        out = []
        for name, dim in zip(logical_axes, shape):
            axes = self.resolve_dim(name, dim, used)
            if axes is None:
                out.append(None)
            else:
                used.update(axes)
                out.append(axes if len(axes) > 1 else axes[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, logical_axes: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def tree_shardings(rules: AxisRules, params, specs):
    """NamedSharding tree for a (params, logical-specs) pair of trees."""
    def one(p, s):
        shape = p.shape if hasattr(p, "shape") else ()
        return rules.sharding(tuple(s), tuple(shape))
    return jax.tree.map(one, params, specs,
                        is_leaf=lambda x: isinstance(x, tuple))


def tree_pspecs(rules: AxisRules, params, specs):
    def one(p, s):
        shape = p.shape if hasattr(p, "shape") else ()
        return rules.spec(tuple(s), tuple(shape))
    return jax.tree.map(one, params, specs,
                        is_leaf=lambda x: isinstance(x, tuple))


# ------------------------------------------------------- activation context --

_TLS = threading.local()


@contextmanager
def use_rules(rules: AxisRules | None):
    """Enable `shard(x, ...)` activation constraints during tracing."""
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield rules
    finally:
        _TLS.rules = prev


def current_rules() -> AxisRules | None:
    return getattr(_TLS, "rules", None)


def shard(x, *logical):
    """with_sharding_constraint by logical names; no-op outside use_rules()."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(tuple(logical), tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
