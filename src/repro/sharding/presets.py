"""Named sharding-rule presets — the §Perf winners as first-class configs.

Usage:
    rules = AxisRules(mesh).replace(**PRESETS["fulldp_zero"])
or via the launchers: ``--rules fulldp_zero``.
"""
from __future__ import annotations

PRESETS: dict[str, dict] = {
    # paper-faithful baseline: TP over `model`, FSDP over `data`
    "baseline": {},

    # §Perf cell B winner (zamba2 train: 8.2x on the dominant term).
    # Absorb `model` into the batch axes — pure DP compute, ZeRO over
    # `data`. Right whenever per-layer TP psums dominate and weights+
    # moments fit at 1/|data| per device (≲3B params on v5e).
    "fulldp_zero": {
        "act_batch": ("pod", "data", "model"),
        "act_inner": None, "act_heads": None, "act_kv_heads": None,
        "act_mlp": None, "act_vocab": None,
        "inner": None, "heads": None, "kv_heads": None, "mlp": None,
        "vocab": None,
    },

    # §Perf cell C winner (phi3.5 train, with cfg.moe.impl="ep"):
    # Megatron sequence parallelism — inter-block activations stay
    # seq-sharded over `model`; TP all-reduces become RS+AG.
    "seqparallel": {
        "act_seq": ("model",),
        "act_embed": None,
    },

    # §Perf cell A winner (qwen1.5 decode: 93x with cfg.kv_quant="int8"):
    # distributed flash-decode — KV cache seq dim sharded over `model`
    # (rescues every arch whose kv-head count doesn't divide the axis).
    "flashdecode": {
        "act_kv_seq": ("model",),
    },
}


def resolve(name: str) -> dict:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown rules preset {name!r}; "
                       f"choose from {sorted(PRESETS)}") from None
