"""Distributed spans for the serving stack — mint, propagate, collect, export.

A *trace* is one request's tree of timed spans across the client/worker
boundary; a :class:`SpanContext` (``trace_id``, ``span_id``) names a node
in it.  The context is minted client-side at dispatch (sampling decides
whether this request records at all), rides the wire envelope as an
additive header field, and worker-side spans come back attached to the
RESULT/ERROR envelope — no separate export channel, no clock sync beyond
both processes stamping wall-clock epoch seconds.

Hot-path contract: every instrumentation site first checks
``TRACER.enabled`` (one attribute load); with tracing off (the default)
nothing else runs and :attr:`Tracer.calls` stays 0 — the overhead guard
in ``tests/test_obs.py`` pins this.  Sampled-out traces cost one sampler
roll at the root and nothing per child (children of an unsampled root get
the no-op handle).

Export is Chrome-trace JSON (:func:`export_chrome` / :func:`dump_trace`):
load the file in ``chrome://tracing`` or Perfetto.  Span linkage
(``trace_id`` / ``span_id`` / ``parent_span_id``) rides in each event's
``args`` so tools — and CI — can rebuild the tree exactly.
"""
from __future__ import annotations

import json
import os
import random
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping

__all__ = ["Sampler", "Span", "SpanContext", "Tracer", "TRACER",
           "RemoteSpans", "bound", "configure", "current", "dump_trace",
           "enabled", "export_chrome"]


@dataclass(frozen=True)
class SpanContext:
    """The wire-portable name of one span: enough to parent children under
    it from any process.  ``t_start`` (epoch s) lets the receiving side
    derive queue-wait spans without carrying a separate timestamp."""
    trace_id: str
    span_id: str
    t_start: float = 0.0

    def to_wire(self) -> dict:
        return {"tid": self.trace_id, "sid": self.span_id,
                "t0": round(self.t_start, 6)}

    @classmethod
    def from_wire(cls, d: Mapping[str, Any] | None) -> "SpanContext | None":
        if not d or "tid" not in d or "sid" not in d:
            return None
        return cls(trace_id=str(d["tid"]), span_id=str(d["sid"]),
                   t_start=float(d.get("t0", 0.0)))


@dataclass
class Span:
    """One finished span (the ring buffer element)."""
    name: str
    trace_id: str
    span_id: str
    parent_id: str | None
    t_start: float                 # epoch seconds (cross-process timebase)
    dur_s: float
    pid: int
    proc: str                      # "client" | "worker"
    thread: str
    status: str = "ok"
    attrs: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"name": self.name, "tid": self.trace_id,
                "sid": self.span_id, "parent": self.parent_id,
                "t0": self.t_start, "dur": self.dur_s, "pid": self.pid,
                "proc": self.proc, "thread": self.thread,
                "status": self.status, "attrs": self.attrs}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Span":
        return cls(name=str(d.get("name", "?")),
                   trace_id=str(d.get("tid", "")),
                   span_id=str(d.get("sid", "")),
                   parent_id=d.get("parent"),
                   t_start=float(d.get("t0", 0.0)),
                   dur_s=float(d.get("dur", 0.0)),
                   pid=int(d.get("pid", 0)),
                   proc=str(d.get("proc", "client")),
                   thread=str(d.get("thread", "")),
                   status=str(d.get("status", "ok")),
                   attrs=dict(d.get("attrs", {})))


class Sampler:
    """Seeded head-based sampler: one roll per trace root.  Deterministic —
    two samplers with the same seed admit the same decision sequence
    (``tests/test_obs.py`` pins this), so a benchmark re-run traces the
    same requests."""

    def __init__(self, sample: float = 0.0, seed: int = 0):
        self.sample = float(sample)
        self.seed = seed
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def decide(self) -> bool:
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        with self._lock:
            return self._rng.random() < self.sample


class _NoopHandle:
    """The disabled/unsampled span: every operation is a no-op and the
    handle is falsy, so ``if sp:`` guards optional attribute work."""
    __slots__ = ()
    ctx = None

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        pass

    def set(self, *a, **kw) -> None:
        pass

    def finish(self, status: str = "ok") -> None:
        pass

    def __bool__(self) -> bool:
        return False


NOOP = _NoopHandle()


class _SpanHandle:
    """A live span: context manager or manually ``finish()``-ed (exactly
    once).  ``set`` adds attributes; an exception leaving the ``with``
    marks status=error and records the exception type/message."""

    __slots__ = ("_sink", "name", "ctx", "parent_id", "_t0_perf", "attrs",
                 "_proc", "_done")

    def __init__(self, sink, name: str, ctx: SpanContext,
                 parent_id: str | None, proc: str, attrs: dict):
        self._sink = sink
        self.name = name
        self.ctx = ctx
        self.parent_id = parent_id
        self.attrs = attrs
        self._proc = proc
        self._t0_perf = time.perf_counter()
        self._done = False

    def set(self, key: str, value) -> None:
        self.attrs[key] = value

    def finish(self, status: str = "ok") -> None:
        if self._done:
            return
        self._done = True
        self._sink(Span(
            name=self.name, trace_id=self.ctx.trace_id,
            span_id=self.ctx.span_id, parent_id=self.parent_id,
            t_start=self.ctx.t_start,
            dur_s=time.perf_counter() - self._t0_perf,
            pid=os.getpid(), proc=self._proc,
            thread=threading.current_thread().name,
            status=status, attrs=self.attrs))

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, etype, err, tb) -> None:
        if etype is not None:
            self.attrs.setdefault("error.type", etype.__name__)
            self.attrs.setdefault("error.message", str(err))
            self.finish("error")
        else:
            self.finish()

    def __bool__(self) -> bool:
        return True


_ids = random.Random()          # span/trace id minting (uniqueness only)
_id_lock = threading.Lock()


def _new_id(bits: int = 64) -> str:
    with _id_lock:
        return f"{_ids.getrandbits(bits):0{bits // 4}x}"


class Tracer:
    """Span factory + in-memory ring-buffer collector.

    ``enabled`` is the hard off-switch; ``sampler`` decides per trace
    root.  ``calls`` counts real instrumentation engagements (handles
    created / spans ingested) — the disabled-overhead guard asserts it
    stays 0 with tracing off.
    """

    def __init__(self, *, enabled: bool = False, sample: float = 0.0,
                 seed: int = 0, ring: int = 65536, proc: str = "client"):
        self.enabled = bool(enabled)
        self.sampler = Sampler(sample, seed)
        self.proc = proc
        self.calls = 0
        self._lock = threading.Lock()
        self._ring: deque[Span] = deque(maxlen=max(1, ring))
        self._local = threading.local()

    # ----------------------------------------------------------- configure
    def configure(self, *, enabled: bool | None = None,
                  sample: float | None = None, seed: int | None = None,
                  ring: int | None = None) -> None:
        if sample is not None or seed is not None:
            self.sampler = Sampler(
                self.sampler.sample if sample is None else sample,
                self.sampler.seed if seed is None else seed)
        if enabled is not None:
            self.enabled = bool(enabled)
        elif sample is not None:
            # setting a positive sample IS the opt-in; sample=0 hard-disables
            self.enabled = sample > 0.0
        if ring is not None:
            with self._lock:
                self._ring = deque(self._ring, maxlen=max(1, ring))

    def reset(self) -> None:
        with self._lock:
            self._ring.clear()
        self.calls = 0

    # ----------------------------------------------------- context plumbing
    def current(self) -> SpanContext | None:
        return getattr(self._local, "ctx", None)

    def set_current(self, ctx: SpanContext | None):
        prev = getattr(self._local, "ctx", None)
        self._local.ctx = ctx
        return prev

    # ------------------------------------------------------------ spanning
    def start_trace(self, name: str, **attrs):
        """Mint a trace root — the sampling decision happens here; children
        of an unsampled root are no-ops all the way down."""
        if not self.enabled or not self.sampler.decide():
            return NOOP
        self.calls += 1
        ctx = SpanContext(_new_id(64), _new_id(64), time.time())
        return _SpanHandle(self._record, name, ctx, None, self.proc, attrs)

    def span(self, name: str, parent: SpanContext | None = None, **attrs):
        """A child span under ``parent`` (or the thread's current context).
        No parent → no span: orphan spans cannot stitch into any tree."""
        if not self.enabled:
            return NOOP
        if parent is None:
            parent = self.current()
            if parent is None:
                return NOOP
        self.calls += 1
        ctx = SpanContext(parent.trace_id, _new_id(64), time.time())
        return _SpanHandle(self._record, name, ctx, parent.span_id,
                           self.proc, attrs)

    def span_at(self, name: str, parent: SpanContext, t_start: float,
                dur_s: float, status: str = "ok", **attrs) -> None:
        """Record an already-elapsed interval (e.g. queue wait derived from
        the context's mint time) as a finished span."""
        if not self.enabled:
            return
        self.calls += 1
        self._record(Span(
            name=name, trace_id=parent.trace_id, span_id=_new_id(64),
            parent_id=parent.span_id, t_start=t_start, dur_s=dur_s,
            pid=os.getpid(), proc=self.proc,
            thread=threading.current_thread().name, status=status,
            attrs=attrs))

    # ------------------------------------------------------------- collect
    def _record(self, span: Span) -> None:
        with self._lock:
            self._ring.append(span)

    def ingest(self, span_dicts: Iterable[Mapping]) -> None:
        """Adopt spans another process recorded (worker spans riding the
        RESULT envelope) into this collector's ring."""
        if not self.enabled or not span_dicts:
            return
        self.calls += 1
        with self._lock:
            for d in span_dicts:
                try:
                    self._ring.append(Span.from_dict(d))
                except (TypeError, ValueError):
                    continue           # a malformed span must not kill a reply

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._ring)

    # -------------------------------------------------------------- export
    def export_chrome(self) -> dict:
        return export_chrome(self.spans())

    def dump(self, path: str) -> int:
        """Write Chrome-trace JSON; returns the number of events written."""
        doc = self.export_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        return len(doc["traceEvents"])


class RemoteSpans:
    """Worker-side span batch for ONE request.

    The worker records spans only when the incoming envelope carries a
    trace context (the client already made the sampling decision), and the
    finished spans ship back on the reply envelope — the worker keeps
    nothing.  ``span(name)`` parents under the client's context by
    default; pass ``parent=`` (a handle's ``.ctx``) to nest deeper.
    """

    def __init__(self, wire_ctx: Mapping[str, Any] | None,
                 proc: str = "worker"):
        self.ctx = SpanContext.from_wire(wire_ctx)
        self.proc = proc
        self._spans: list[Span] = []

    def __bool__(self) -> bool:
        return self.ctx is not None

    def span(self, name: str, parent: SpanContext | None = None, **attrs):
        if self.ctx is None:
            return NOOP
        parent = parent or self.ctx
        ctx = SpanContext(parent.trace_id, _new_id(64), time.time())
        return _SpanHandle(self._spans.append, name, ctx, parent.span_id,
                           self.proc, attrs)

    def span_at(self, name: str, t_start: float, dur_s: float,
                **attrs) -> None:
        if self.ctx is None:
            return
        self._spans.append(Span(
            name=name, trace_id=self.ctx.trace_id, span_id=_new_id(64),
            parent_id=self.ctx.span_id, t_start=t_start, dur_s=dur_s,
            pid=os.getpid(), proc=self.proc,
            thread=threading.current_thread().name, attrs=attrs))

    def dicts(self) -> list[dict]:
        return [s.to_dict() for s in self._spans]


# ----------------------------------------------------------------- export --

def export_chrome(spans: Iterable[Span]) -> dict:
    """Chrome-trace/Perfetto JSON: complete ('X') events, microsecond
    timestamps on the shared epoch timebase; span linkage in ``args``."""
    events = []
    for s in spans:
        events.append({
            "name": s.name, "cat": s.proc, "ph": "X",
            "ts": s.t_start * 1e6, "dur": max(0.0, s.dur_s) * 1e6,
            "pid": s.pid, "tid": abs(hash(s.thread)) % (1 << 31),
            "args": {"trace_id": s.trace_id, "span_id": s.span_id,
                     "parent_span_id": s.parent_id, "proc": s.proc,
                     "thread": s.thread, "status": s.status, **s.attrs}})
    return {"displayTimeUnit": "ms", "traceEvents": events}


# ------------------------------------------------------- module-level API --

#: the process tracer — sessions configure it, exporters read it
TRACER = Tracer()


def configure(**kwargs) -> None:
    """``obs.configure(sample=1.0)`` / ``obs.configure(enabled=False)`` —
    see :meth:`Tracer.configure`."""
    TRACER.configure(**kwargs)


def enabled() -> bool:
    return TRACER.enabled


def current() -> SpanContext | None:
    return TRACER.current()


def dump_trace(path: str) -> int:
    return TRACER.dump(path)


class bound:
    """Bind a span context to a callable for cross-thread propagation:
    ``executor.submit(bound(ctx, fn), *args)`` makes ``fn`` (and anything
    it dispatches) parent under ``ctx`` even on another thread."""

    __slots__ = ("ctx", "fn")

    def __init__(self, ctx: SpanContext | None, fn):
        self.ctx = ctx
        self.fn = fn

    def __call__(self, *args, **kwargs):
        if self.ctx is None:
            return self.fn(*args, **kwargs)
        prev = TRACER.set_current(self.ctx)
        try:
            return self.fn(*args, **kwargs)
        finally:
            TRACER.set_current(prev)
