"""``repro.obs`` — request tracing and the metrics plane (ISSUE 8).

Two small, dependency-free subsystems that make every hop of an
invocation observable:

* :mod:`repro.obs.trace` — distributed spans.  A ``(trace_id, span_id)``
  context is minted client-side at dispatch, rides the wire envelope as
  an additive header field (old workers ignore it), and worker-side spans
  ship back on the RESULT/ERROR envelope so one request's client spans
  (submit → queue → transport) and worker spans (decode, cold compile,
  entry) stitch into a single tree.  Export is Chrome-trace JSON
  (``chrome://tracing`` / Perfetto) via :func:`dump_trace`.
* :mod:`repro.obs.metrics` — process-local counters / gauges /
  fixed-bucket histograms with Prometheus text exposition.  These replace
  the ad-hoc stats dicts that used to live in ``runtime/sandbox.py`` and
  are aggregated worker→client over the existing ``host_stats`` CONTROL
  verb (see ``Session.stats()['metrics']``).

The tracing hot path honors a hard off-switch: with tracing disabled
(the default — ``sample=0``), every instrumentation site is one
attribute load and a falsy check, and the tracer's ``calls`` counter
stays at zero (guarded by ``tests/test_obs.py``).  Metrics are always on
— they are the same counters the sandbox host always kept, just uniform.
"""
from __future__ import annotations

from . import metrics, trace
from .metrics import REGISTRY, Counter, Gauge, Histogram, Registry
from .trace import (RemoteSpans, Sampler, Span, SpanContext, Tracer,
                    TRACER, bound, configure, current, dump_trace, enabled,
                    export_chrome)

__all__ = [
    "metrics", "trace",
    "REGISTRY", "Counter", "Gauge", "Histogram", "Registry",
    "RemoteSpans", "Sampler", "Span", "SpanContext", "Tracer", "TRACER",
    "bound", "configure", "current", "dump_trace", "enabled",
    "export_chrome",
]
