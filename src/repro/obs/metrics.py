"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`Registry` holds named metrics; every metric supports optional
labels (``counter.inc(function="f")``).  The design goals, in order:

* **lock-cheap** — one ``threading.Lock`` per metric, taken only around a
  dict/list increment; no global lock on the hot path;
* **mergeable** — :meth:`Registry.snapshot` produces a plain-JSON dict and
  :meth:`Registry.merge` folds another process's snapshot in (counters and
  histogram series sum, gauges sum — across workers a summed gauge is the
  fleet total).  This is how worker metrics travel to the client over the
  existing ``host_stats`` CONTROL verb without a new wire kind;
* **renderable** — :func:`render` emits Prometheus text exposition
  (``GET /metrics`` on the http worker host serves it).

The module-level :data:`REGISTRY` is the process default (transport and
scheduler metrics); components that need per-instance scoping (one
``SandboxHost`` per backend/test) own a private ``Registry`` and surface
it through their ``stats()``.
"""
from __future__ import annotations

import bisect
import threading
from typing import Iterable, Mapping

# fixed default buckets: milliseconds-flavored, covering sub-ms transport
# hops up to multi-second cold compiles
DEFAULT_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                      500.0, 1000.0, 2000.0, 5000.0, 10000.0)
# seconds-flavored twin for busy-time style histograms
DEFAULT_BUCKETS_S = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                     60.0)


def _label_key(labels: Mapping[str, object]) -> str:
    """Canonical label encoding — doubles as the Prometheus label body."""
    if not labels:
        return ""
    return ",".join(f'{k}="{_escape(str(v))}"'
                    for k, v in sorted(labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonic counter, optionally labeled."""
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: dict[str, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    @property
    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())

    def snapshot(self) -> dict:
        with self._lock:
            return {"type": self.kind, "help": self.help,
                    "values": dict(self._values)}


class Gauge(Counter):
    """Settable value (queue depths, live instances).  ``merge`` sums
    gauges across snapshots — the fleet-wide total of a per-worker gauge."""
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative bucket counts + sum + count,
    Prometheus-shaped.  Bucket bounds are frozen at construction, so two
    processes' series always merge bucket-for-bucket."""
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS_MS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("a histogram needs at least one bucket bound")
        # per label-set: [per-bucket counts..., overflow], sum, count
        self._series: dict[str, dict] = {}

    def _slot(self, key: str) -> dict:
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = {"counts": [0] * (len(self.buckets) + 1),
                                     "sum": 0.0, "count": 0}
        return s

    def observe(self, value: float, **labels) -> None:
        idx = bisect.bisect_left(self.buckets, value)
        key = _label_key(labels)
        with self._lock:
            s = self._slot(key)
            s["counts"][idx] += 1
            s["sum"] += value
            s["count"] += 1

    def series(self, **labels) -> dict:
        with self._lock:
            s = self._slot(_label_key(labels))
            return {"counts": list(s["counts"]), "sum": s["sum"],
                    "count": s["count"]}

    def cumulative(self, **labels) -> list[int]:
        """Per-bound cumulative counts (… plus the +Inf total last)."""
        s = self.series(**labels)
        out, acc = [], 0
        for c in s["counts"]:
            acc += c
            out.append(acc)
        return out

    def snapshot(self) -> dict:
        with self._lock:
            return {"type": self.kind, "help": self.help,
                    "buckets": list(self.buckets),
                    "series": {k: {"counts": list(s["counts"]),
                                   "sum": s["sum"], "count": s["count"]}
                               for k, s in self._series.items()}}


class Registry:
    """Named metrics, get-or-create: calling ``registry.counter(name)``
    twice returns the same object (modules register at import or first
    use without coordination)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, **kwargs) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kwargs)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)      # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)        # type: ignore[return-value]

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS_MS) -> Histogram:
        return self._get(Histogram, name, help,    # type: ignore[return-value]
                         buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    # ----------------------------------------------------------- aggregate
    def snapshot(self) -> dict:
        """Plain-JSON view of every metric — what rides ``host_stats``."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: m.snapshot() for m in metrics}

    def merge(self, snap: Mapping[str, Mapping] | None) -> None:
        """Fold another process's :meth:`snapshot` into this registry —
        counters/gauges/histogram series sum elementwise.  Unknown metric
        names are created; bucket-bound mismatches skip that metric rather
        than corrupt the series."""
        if not snap:
            return
        for name, m in snap.items():
            kind = m.get("type")
            if kind == "counter" or kind == "gauge":
                cls = Gauge if kind == "gauge" else Counter
                dst = self._get(cls, name, m.get("help", ""))
                with dst._lock:
                    for key, v in m.get("values", {}).items():
                        dst._values[key] = dst._values.get(key, 0.0) + v
            elif kind == "histogram":
                buckets = tuple(float(b) for b in m.get("buckets", ()))
                try:
                    dst = self._get(Histogram, name, m.get("help", ""),
                                    buckets=buckets or DEFAULT_BUCKETS_MS)
                except TypeError:
                    continue
                if dst.buckets != buckets:
                    continue
                with dst._lock:
                    for key, s in m.get("series", {}).items():
                        d = dst._slot(key)
                        counts = s.get("counts", [])
                        if len(counts) != len(d["counts"]):
                            continue
                        d["counts"] = [a + b
                                       for a, b in zip(d["counts"], counts)]
                        d["sum"] += s.get("sum", 0.0)
                        d["count"] += s.get("count", 0)

    def render(self) -> str:
        return render_snapshot(self.snapshot())


def render(registries: Iterable[Registry]) -> str:
    """Prometheus text exposition over several registries merged (the http
    worker serves its sandbox host's registry plus the process default)."""
    merged = Registry()
    for r in registries:
        merged.merge(r.snapshot())
    return merged.render()


def render_snapshot(snap: Mapping[str, Mapping]) -> str:
    """Prometheus text exposition (version 0.0.4) from a snapshot dict."""
    lines: list[str] = []
    for name in sorted(snap):
        m = snap[name]
        kind = m.get("type", "untyped")
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} {kind}")
        if kind in ("counter", "gauge"):
            values = m.get("values", {}) or {"": 0.0}
            for key in sorted(values):
                label = f"{{{key}}}" if key else ""
                lines.append(f"{name}{label} {_fmt(values[key])}")
        elif kind == "histogram":
            bounds = m.get("buckets", [])
            series = m.get("series", {}) or {"": {"counts": [0] * (
                len(bounds) + 1), "sum": 0.0, "count": 0}}
            for key in sorted(series):
                s = series[key]
                acc = 0
                for bound, c in zip(list(bounds) + ["+Inf"], s["counts"]):
                    acc += c
                    le = bound if bound == "+Inf" else _fmt(bound)
                    label = f'{key},le="{le}"' if key else f'le="{le}"'
                    lines.append(f"{name}_bucket{{{label}}} {acc}")
                label = f"{{{key}}}" if key else ""
                lines.append(f"{name}_sum{label} {_fmt(s['sum'])}")
                lines.append(f"{name}_count{label} {s['count']}")
    return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


#: process-default registry — transport, scheduler, and worker-host metrics
REGISTRY = Registry()
