# NOTE: deliberately does NOT import dryrun (it sets XLA_FLAGS at import).
