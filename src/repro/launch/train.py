"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --batch 8 --seq 128 [--smoke] [--ckpt DIR] [--fail-at 60]

Uses the real config by default (with the production mesh when more than
one device is available) or the reduced smoke config for CPU runs.
"""
from __future__ import annotations

import argparse
import json

from ..configs import get_config, get_smoke
from ..runtime.trainer import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated preemptions at these steps")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)

    def on_step(step, metrics):
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)

    report = train(cfg, steps=args.steps, global_batch=args.batch,
                   seq_len=args.seq, ckpt_dir=args.ckpt,
                   ckpt_every=args.ckpt_every, peak_lr=args.lr,
                   fail_at=set(args.fail_at), on_step=on_step)
    print(json.dumps({
        "arch": cfg.name, "steps_run": report.steps_run,
        "restarts": report.restarts, "restored_from": report.restored_from,
        "first_loss": report.losses[0] if report.losses else None,
        "final_loss": report.final_loss,
        "mean_step_s": (sum(report.step_times_s[1:])
                        / max(1, len(report.step_times_s) - 1)),
    }, indent=1))


if __name__ == "__main__":
    main()
