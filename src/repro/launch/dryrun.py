import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell against the
production mesh, print memory/cost analysis, extract roofline terms.

The two lines above MUST run before any jax import (device count locks at
first init) and must not leak into tests/benches — those see 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --cell train_4k
  python -m repro.launch.dryrun --arch all [--multipod] [--out experiments/dryrun]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, SHAPES, cells, get_config       # noqa: E402
from ..models import build_model, input_specs, make_train_step  # noqa: E402
from ..models.api import cache_specs                           # noqa: E402
from ..optim import AdamW                                      # noqa: E402
from ..sharding import AxisRules, tree_shardings, use_rules    # noqa: E402
from .mesh import make_production_mesh                         # noqa: E402
from . import roofline as rl                                   # noqa: E402


def _eval_init(model, key):
    """Abstract params + the static logical-spec tree, no allocation."""
    box = {}

    def f(k):
        p, s = model.init(k)
        box["s"] = s
        return p

    avals = jax.eval_shape(f, key)
    return avals, box["s"]


def batch_shardings(rules: AxisRules, batch_avals):
    logical = {
        "tokens": ("act_batch", "act_seq"),
        "labels": ("act_batch", "act_seq"),
        "embeds": ("act_batch", "act_seq", "act_embed"),
        "frames": ("act_batch", "act_seq", "act_embed"),
        "pos3d": (None, "act_batch", "act_seq"),
    }
    return {k: rules.sharding(logical[k], v.shape)
            for k, v in batch_avals.items()}


def lower_cell(arch: str, cell: str, mesh, rules: AxisRules,
               overrides: dict | None = None):
    """Returns (lowered, compiled, meta) for one cell on one mesh."""
    cfg = get_config(arch)
    # dry-run defaults: unrolled layers (exact cost attribution — XLA's
    # HloCostAnalysis counts a while body once) + the chunked-XLA attention
    # (the Pallas kernel is runtime-only; interpret mode can't partition).
    cfg = cfg.replace(attn_impl="xla", scan_layers=False)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[cell]
    model = build_model(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p_avals, p_specs = _eval_init(model, key)
    p_sh = tree_shardings(rules, p_avals, p_specs)
    specs = input_specs(cfg, shape)

    with use_rules(rules):
        if shape.kind == "train":
            opt = AdamW(total_steps=10_000)
            o_avals = jax.eval_shape(opt.init, p_avals)
            o_specs = opt.state_specs(p_specs)
            o_sh = tree_shardings(rules, o_avals, o_specs)
            b_sh = batch_shardings(rules, specs["batch"])
            step = make_train_step(model, opt)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_avals, o_avals, specs["batch"])
        elif shape.kind == "prefill":
            b_sh = batch_shardings(rules, specs["batch"])
            jitted = jax.jit(model.prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_avals, specs["batch"])
        else:  # decode
            c_specs = cache_specs(cfg)
            c_sh = tree_shardings(rules, specs["cache"], c_specs)
            t_sh = rules.sharding(("act_batch", None),
                                  specs["tokens"].shape)
            jitted = jax.jit(model.decode,
                             in_shardings=(p_sh, c_sh, t_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_avals, specs["cache"], specs["tokens"])

    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    meta = {"arch": arch, "cell": cell, "kind": shape.kind,
            "compile_s": compile_s,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "fallbacks": sorted(set(map(str, rules.fallbacks)))}
    return lowered, compiled, meta


def run_cell(arch: str, cell: str, *, multi_pod: bool, out_dir: str | None,
             verbose: bool = True, overrides: dict | None = None,
             rule_overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = AxisRules(mesh)
    if rule_overrides:
        rules = rules.replace(**rule_overrides)
    # multi-pod pass proves the `pod` axis shards (scan: 12x faster compile);
    # the single-pod pass is unrolled for exact roofline cost attribution.
    if overrides is None:
        overrides = {"scan_layers": True} if multi_pod else {}
    lowered, compiled, meta = lower_cell(arch, cell, mesh, rules,
                                         overrides=overrides)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                mem[k] = getattr(ma, k, None)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    roof = rl.from_compiled(compiled, mesh)
    shape = SHAPES[cell]
    cfg = get_config(arch)
    mf = rl.model_flops(cfg, shape)
    rec = {
        **meta,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "memory_analysis": mem,
        "roofline": roof.as_dict(),
        "model_flops": mf,
        "useful_flops_frac": mf / roof.global_flops if roof.flops else None,
    }
    if verbose:
        print(json.dumps(rec, indent=1, default=str))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{cell}_{rec['mesh']}".replace("/", "-")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--rules", default="baseline",
                    help="sharding preset (see repro.sharding.PRESETS)")
    args = ap.parse_args()
    from ..sharding.presets import resolve
    rule_overrides = resolve(args.rules)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    failures = []
    for arch in archs:
        cell_list = cells(arch) if args.cell == "all" else [args.cell]
        for cell in cell_list:
            for mp in meshes:
                tag = f"{arch}_{cell}_{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.perf_counter()
                try:
                    run_cell(arch, cell, multi_pod=mp, out_dir=args.out,
                             verbose=False, rule_overrides=rule_overrides)
                    print(f"[ok] {tag}  ({time.perf_counter()-t0:.1f}s)",
                          flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    traceback.print_exc()
                    print(f"[FAIL] {tag}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
