"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s bf16)
  memory     = HLO_bytes   / (chips × 819e9 B/s HBM)
  collective = Σ per-op bytes-on-wire / (chips × links × 50e9 B/s ICI)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Calibrated on this
container: XLA analyzes the *partitioned per-device module*, so "flops" is
per-device work (verified: sharded (64,128)@(128,256) on 8 devices reports
global/8) — terms therefore do NOT divide by chips again; global totals are
per-device × chips.  Collective bytes are NOT in cost_analysis: we parse
the optimized HLO and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, weighted by the standard
ring-algorithm wire factors with the op's actual group size.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# `%name = f32[64,128]{1,0} all-reduce(...)` or tuple results
# `%name = (f32[..]{..}, f32[..]{..}) all-reduce-start(...)`
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}() ]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.ASCII)
_SHAPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)\[([0-9,]*)\]",
    re.ASCII)

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,N]<=[...]  -> N participants per group
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    per_op: dict[str, float] = field(default_factory=dict)
    count: dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0

    def add(self, op: str, b: float):
        self.per_op[op] = self.per_op.get(op, 0.0) + b
        self.count[op] = self.count.get(op, 0) + 1
        self.wire_bytes += b


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device bytes-on-wire, summed over collective ops in the module.

    Ring factors (g = group size, S = per-device payload in the op result):
      all-gather:  result is g×input -> wire = S_result × (g-1)/g
      reduce-scatter: wire = S_input × (g-1)/g ≈ S_result × (g-1)
      all-reduce:  wire = 2 × S × (g-1)/g
      all-to-all:  wire = S × (g-1)/g
      collective-permute: wire = S
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result, op = m.group(1), m.group(2)
        size = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result))
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if op == "all-gather":
            wire = size * (g - 1) / g
        elif op == "reduce-scatter":
            wire = size * (g - 1)
        elif op == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif op == "all-to-all":
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = size
        stats.add(op, wire)
    return stats


@dataclass
class Roofline:
    flops: float                  # per-device (see module docstring)
    hbm_bytes: float              # per-device
    coll: CollectiveStats
    chips: int
    links_per_chip: int = 4       # v5e 2D torus: 4 ICI links

    @property
    def global_flops(self) -> float:
        return self.flops * self.chips

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # wire bytes are already per-device (largest-group path)
        return self.coll.wire_bytes / (self.links_per_chip * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound; the dominant term is the overlap bound."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops, "global_flops": self.global_flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_wire_bytes": self.coll.wire_bytes,
            "collective_per_op": self.coll.per_op,
            "collective_count": self.coll.count,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def from_compiled(compiled, mesh) -> Roofline:
    n = mesh.devices.size
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text, n)
    return Roofline(flops=flops, hbm_bytes=hbm, coll=coll, chips=n)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = batch
    (one token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch
