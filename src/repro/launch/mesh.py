"""Production mesh definition.

A FUNCTION (not module-level state) so importing this module never touches
jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis is
    the slowest (DCI-connected) — batch shards over (pod, data)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) != n:
        import numpy as np
        return jax.sharding.Mesh(
            np.asarray(devices[:n]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests/smokes)."""
    import numpy as np
    devices = jax.devices()[: data * model]
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(data, model), ("data", "model"))
