"""Serving launcher: batched requests through a serverless cloud session.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 16 --max-new 8 \
      [--backend threads|inline|sim-aws|processes|http|http-aio] \
      [--mode waves|continuous]

``--backend`` switches the execution backend and ``--mode`` the scheduler
without touching any serving code — the single-source property the
session API guarantees.  ``waves`` is the fixed fork-join client
(``LMServer.serve``); ``continuous`` drives the same pack/unpack core
through the asyncio :class:`~repro.serving.batcher.ContinuousBatcher`
(slot-based admission, decode-length bucketing).  The
``processes``/``http``/``http-aio`` backends run generation in real worker
processes behind the wire protocol; params deploy once to the
content-addressed artifact store and payloads carry the reference.

``--fleet N`` serves through the :class:`~repro.fleet.FleetRouter`
instead: N engine-loop members, each pinned to its own worker, with
prefix-aware routing (``--fleet-policy prefix|p2c|random``), optional
prefill/decode disaggregation (``--fleet-disaggregate``), and elastic
scale-up/drain (``--fleet-elastic``).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..cloud import Session, available_backends
from ..configs import get_config, get_smoke
from ..models import build_model
from ..runtime.server import LMServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--wave", type=int, default=8,
                    help="wave size (waves) / max batch (continuous)")
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    ap.add_argument("--mode", default="waves",
                    choices=("waves", "continuous"))
    ap.add_argument("--slots", type=int, default=2,
                    help="continuous mode: in-flight decode batches/arenas")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="continuous mode: batch-fill wait")
    ap.add_argument("--iteration", default="auto",
                    choices=("auto", "on", "off"),
                    help="continuous mode: iteration-level scheduling "
                         "(worker-resident KV arena; auto = when the "
                         "backend supports resident state)")
    ap.add_argument("--quantum", type=int, default=8,
                    help="iteration mode: decode steps per chunk")
    ap.add_argument("--prefix-tokens", type=int, default=1 << 16,
                    help="iteration mode: prompt-prefix cache budget "
                         "(tokens; 0 disables)")
    ap.add_argument("--paged", default="off", choices=("on", "off"),
                    help="iteration mode: paged KV arena (block-table "
                         "attention, radix prefix sharing, chunked "
                         "prefill)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged mode: tokens per KV block")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve through a FleetRouter with N members "
                         "(overrides --mode)")
    ap.add_argument("--fleet-policy", default="prefix",
                    choices=("prefix", "p2c", "random", "radix"))
    ap.add_argument("--fleet-elastic", default="off", choices=("on", "off"),
                    help="start at --fleet-min members, grow under backlog, "
                         "drain on sustained low occupancy")
    ap.add_argument("--fleet-min", type=int, default=1)
    ap.add_argument("--fleet-disaggregate", default="off",
                    choices=("on", "off"),
                    help="split members into prefill and decode roles; "
                         "prefilled rows migrate over CONTROL frames")
    ap.add_argument("--fleet-prefill", type=int, default=1,
                    help="disaggregated mode: prefill member count")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(args.backend)
    server = LMServer(cfg, params, session=session, max_new=args.max_new)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    fleet_summary = None
    if args.fleet > 0:
        from ..fleet import run_fleet
        comps, fleet_summary = run_fleet(
            server, reqs, concurrency=args.requests,
            n_members=args.fleet, policy=args.fleet_policy,
            elastic=args.fleet_elastic == "on", min_members=args.fleet_min,
            disaggregate=args.fleet_disaggregate == "on",
            prefill_members=args.fleet_prefill,
            max_batch=args.wave, quantum=args.quantum,
            prompt_cap=max(8, args.prompt_len),
            prefix_tokens=args.prefix_tokens,
            paged=args.paged == "on", block_size=args.block_size,
            return_stats=True)
    elif args.mode == "continuous":
        from ..serving import run_continuous
        iteration = {"auto": None, "on": True, "off": False}[args.iteration]
        comps = run_continuous(server, reqs, concurrency=args.requests,
                               max_batch=args.wave, slots=args.slots,
                               max_wait_ms=args.max_wait_ms,
                               iteration_level=iteration,
                               quantum=args.quantum,
                               prompt_cap=max(8, args.prompt_len),
                               prefix_tokens=args.prefix_tokens,
                               paged=args.paged == "on",
                               block_size=args.block_size)
    else:
        comps = server.serve(reqs, wave_size=args.wave)
    wall = time.perf_counter() - t0
    doc = {
        "arch": cfg.name, "backend": args.backend,
        "mode": f"fleet-{args.fleet}" if args.fleet > 0 else args.mode,
        "requests": len(comps),
        "wall_s": round(wall, 3),
        "tokens_generated": sum(len(c.tokens) for c in comps),
        "cost": server.cost_report.summary(),
        "sample": comps[0].tokens,
    }
    if fleet_summary is not None:
        doc["fleet"] = fleet_summary
        doc["workers"] = session.stats()
    print(json.dumps(doc, indent=1))
    server.close()
    session.close()


if __name__ == "__main__":
    main()
