"""Serving launcher: batched requests through a serverless cloud session.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 16 --max-new 8 \
      [--backend threads|inline|sim-aws|processes|http|http-aio] \
      [--mode waves|continuous]

``--backend`` switches the execution backend and ``--mode`` the scheduler
without touching any serving code — the single-source property the
session API guarantees.  ``waves`` is the fixed fork-join client
(``LMServer.serve``); ``continuous`` drives the same pack/unpack core
through the asyncio :class:`~repro.serving.batcher.ContinuousBatcher`
(slot-based admission, decode-length bucketing).  The
``processes``/``http``/``http-aio`` backends run generation in real worker
processes behind the wire protocol; params deploy once to the
content-addressed artifact store and payloads carry the reference.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..cloud import Session, available_backends
from ..configs import get_config, get_smoke
from ..models import build_model
from ..runtime.server import LMServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--wave", type=int, default=8,
                    help="wave size (waves) / max batch (continuous)")
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    ap.add_argument("--mode", default="waves",
                    choices=("waves", "continuous"))
    ap.add_argument("--slots", type=int, default=2,
                    help="continuous mode: in-flight decode batches/arenas")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="continuous mode: batch-fill wait")
    ap.add_argument("--iteration", default="auto",
                    choices=("auto", "on", "off"),
                    help="continuous mode: iteration-level scheduling "
                         "(worker-resident KV arena; auto = when the "
                         "backend supports resident state)")
    ap.add_argument("--quantum", type=int, default=8,
                    help="iteration mode: decode steps per chunk")
    ap.add_argument("--prefix-tokens", type=int, default=1 << 16,
                    help="iteration mode: prompt-prefix cache budget "
                         "(tokens; 0 disables)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(args.backend)
    server = LMServer(cfg, params, session=session, max_new=args.max_new)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    if args.mode == "continuous":
        from ..serving import run_continuous
        iteration = {"auto": None, "on": True, "off": False}[args.iteration]
        comps = run_continuous(server, reqs, concurrency=args.requests,
                               max_batch=args.wave, slots=args.slots,
                               max_wait_ms=args.max_wait_ms,
                               iteration_level=iteration,
                               quantum=args.quantum,
                               prompt_cap=max(8, args.prompt_len),
                               prefix_tokens=args.prefix_tokens)
    else:
        comps = server.serve(reqs, wave_size=args.wave)
    wall = time.perf_counter() - t0
    print(json.dumps({
        "arch": cfg.name, "backend": args.backend, "mode": args.mode,
        "requests": len(comps),
        "wall_s": round(wall, 3),
        "tokens_generated": sum(len(c.tokens) for c in comps),
        "cost": server.cost_report.summary(),
        "sample": comps[0].tokens,
    }, indent=1))
    server.close()
    session.close()


if __name__ == "__main__":
    main()
