"""Serving launcher: batched requests through a serverless cloud session.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 16 --max-new 8 \
      [--backend threads|inline|sim-aws|processes|http]

``--backend`` switches the execution backend without touching any serving
code — the single-source property the session API guarantees.  The
``processes``/``http`` backends run generation in real worker processes
behind the wire protocol (model params ship with each payload; see
API.md's backend-selection notes for when that trade-off pays off).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..cloud import Session, available_backends
from ..configs import get_config, get_smoke
from ..models import build_model
from ..runtime.server import LMServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--wave", type=int, default=8)
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(args.backend)
    server = LMServer(cfg, params, session=session, max_new=args.max_new)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    max_new=args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    comps = server.serve(reqs, wave_size=args.wave)
    wall = time.perf_counter() - t0
    print(json.dumps({
        "arch": cfg.name, "backend": args.backend, "requests": len(comps),
        "wall_s": round(wall, 3),
        "tokens_generated": sum(len(c.tokens) for c in comps),
        "cost": server.cost_report.summary(),
        "sample": comps[0].tokens,
    }, indent=1))
    session.close()


if __name__ == "__main__":
    main()
