"""Continuous batching for :class:`~repro.runtime.server.LMServer`.

Wave mode pre-partitions requests into fixed batches and fork-joins them —
fine for offline bulk, wrong for traffic: a request arriving just after a
wave sealed waits a full wave, and every member of a wave decodes as far
as its longest neighbour.  The :class:`ContinuousBatcher` replaces the
fixed partition with slot-based admission, at one of two granularities:

* **batch-level** (the PR 3/4 path, any backend): up to ``slots`` decode
  batches in flight; a batch seals on ``max_batch``/``max_wait_ms``,
  grouped by decode-length bucket, and dispatches through the same
  ``submit_wave`` / ``unpack_wave`` core as wave mode.  Admission happens
  *between* batches — each batch re-runs prefill and rebuilds its KV
  cache from scratch.
* **iteration-level** (ISSUE 5, backends with ``resident_state``): one
  :class:`~repro.runtime.engine.EngineClient` per slot owns a worker-
  resident cache arena of ``max_batch`` rows.  Arriving prompts are
  prefilled into free rows (or served from the worker's prompt-prefix
  cache and skipped entirely), decode advances every live row in
  ``quantum``-step chunks, rows evict the moment they hit their
  ``max_new`` (no batch-tail wait), and freed rows are refilled at the
  next chunk boundary.  The KV cache never crosses the wire; each chunk
  ships a handle and returns token ids.  TTFT is the prefill round-trip,
  not the batch tail.

Which one runs is automatic (``iteration_level=None``): iteration-level
when the backend keeps worker-resident state (``inline``/``threads``
process-local; ``processes``/``http``/``http-aio`` via affinity-pinned
workers and CONTROL state leases) *and* the model family supports slot
arenas; the batch-level path otherwise (e.g. ``sim-aws``, encdec).
Requests that cannot fit an arena (prompt above ``prompt_cap``) fall back
to a solo wave per request.  Both granularities are pad-masked end to
end, so a request decodes to the same greedy tokens whichever scheduler
ran it and whatever ragged company it kept.
"""
from __future__ import annotations

import asyncio
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Sequence

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..runtime.sandbox import WorkerCrash
from ..runtime.server import Completion, LMServer, Request, decode_bucket
from .aio import await_invocation

# Failover bound: how many times one row may be replayed before its error
# surfaces.  Replay re-prefills prompt + generated-so-far after worker or
# lease loss (ISSUE 10); a row that keeps landing on dying workers must
# eventually fail rather than orbit the fleet forever.
MAX_ROW_REPLAYS = 3

# serving metrics (process-default registry): the uniform mirrors of the
# scheduler's BatcherStats, queryable through Session.stats()["metrics"]
# and merged fleet-wide with the worker-side registries.  TTFT/TPOT are
# stamped once here (see _LiveRow.token_times_ms) — serve_bench consumes
# these stamps instead of re-deriving.
_M_TTFT = obs_metrics.REGISTRY.histogram(
    "serve_ttft_ms", "time to first token, client-observed (ms)")
_M_TPOT = obs_metrics.REGISTRY.histogram(
    "serve_tpot_ms", "mean inter-token time per request (ms)")
_M_DONE = obs_metrics.REGISTRY.counter(
    "serve_completions_total", "requests served to completion")
_M_CHUNKS = obs_metrics.REGISTRY.counter(
    "serve_decode_chunks_total", "iteration-level decode round-trips")
_M_RECOVERED = obs_metrics.REGISTRY.counter(
    "recovery_rows_total",
    "live rows replayed after worker/state loss instead of failing")


@dataclass
class BatcherStats:
    """Scheduler-side accounting (client latency is measured by callers)."""
    mode: str = "batch"              # "batch" | "iteration"
    requests: int = 0
    batches: int = 0                 # batch-level: dispatched batches
    occupancy_sum: int = 0           # sum of batch sizes / chunk occupancy
    decode_steps: int = 0            # batch: bucket lengths; iter: real steps
    sealed_full: int = 0             # batches sealed by max_batch
    sealed_wait: int = 0             # batches sealed by max_wait
    bucket_histogram: dict = field(default_factory=dict)
    # iteration-level accounting
    admission_groups: int = 0        # prefill round-trips
    decode_chunks: int = 0           # decode round-trips
    prefix_hits: int = 0             # rows whose prefill was skipped
    prefix_misses: int = 0
    wave_fallbacks: int = 0          # requests too big for the arena
    state_resets: int = 0            # arenas rebuilt after state loss
    recovered_rows: int = 0          # live rows replayed instead of failed
    migrated_rows: int = 0           # prefill→decode row hand-offs (fleet)
    # paged-arena occupancy peaks (ISSUE 7), folded from worker replies
    live_tokens_peak: int = 0
    allocated_blocks_peak: int = 0
    shared_blocks_peak: int = 0

    @property
    def mean_batch(self) -> float:
        n = self.batches or self.decode_chunks
        return self.occupancy_sum / n if n else 0.0

    def summary(self) -> dict:
        out = {"mode": self.mode, "requests": self.requests,
               "batches": self.batches,
               "mean_batch": round(self.mean_batch, 2),
               "decode_steps": self.decode_steps,
               "sealed_full": self.sealed_full,
               "sealed_wait": self.sealed_wait,
               "buckets": dict(sorted(self.bucket_histogram.items()))}
        if self.mode == "iteration":
            out.update({"admission_groups": self.admission_groups,
                        "decode_chunks": self.decode_chunks,
                        "prefix_hits": self.prefix_hits,
                        "prefix_misses": self.prefix_misses,
                        "wave_fallbacks": self.wave_fallbacks,
                        "state_resets": self.state_resets,
                        "recovered_rows": self.recovered_rows,
                        "migrated_rows": self.migrated_rows,
                        "live_tokens_peak": self.live_tokens_peak,
                        "allocated_blocks_peak": self.allocated_blocks_peak,
                        "shared_blocks_peak": self.shared_blocks_peak})
        return out


@dataclass
class _LiveRow:
    """One occupied arena slot (iteration-level scheduler bookkeeping)."""
    request: Request
    fut: asyncio.Future
    t_arrival: float
    tokens: list = field(default_factory=list)
    ttft_ms: float = 0.0
    cost_gb_s: float = 0.0
    # one stamp per token, ms since t_arrival, appended at the chunk reply
    # that delivered it (chunk-mates share a stamp); [0] == ttft_ms
    token_times_ms: list = field(default_factory=list)
    # failover bookkeeping (ISSUE 10): how many times this row has been
    # replayed onto a fresh arena, and whether it survived at least one
    recovered: bool = False
    replays: int = 0

    @property
    def remaining(self) -> int:
        return self.request.max_new - len(self.tokens)


class EngineLoop:
    """One worker-resident arena driven step-chunk by step-chunk — the
    iteration-level inner loop, factored out of :class:`ContinuousBatcher`
    so the fleet layer (:mod:`repro.fleet`) can run one per fleet member
    with its own queue, role, and hand-off callbacks (ISSUE 6).

    Roles (disaggregated prefill/decode):

    * ``"unified"`` — prefill and decode in one arena (the PR 5 path; what
      every :class:`ContinuousBatcher` slot runs);
    * ``"prefill"`` — admit/prefill only: each admitted row is extracted
      (its arena slot freed immediately) and passed to ``await
      handoff(items)``, which migrates it into some decode member's
      ``intake``;
    * ``"decode"`` — no prompt admission: pre-filled rows arrive through
      ``intake`` (dicts ``{"entry": migration payload, "row": _LiveRow}``),
      are inserted into this arena, and decode here to completion.

    ``queue`` holds ``(Request, future)`` pairs; ``arrived`` is the shared
    wake-up event (broadcast — every idle loop re-checks its own work
    source after a wake); ``is_closed()`` polls the owner's shutdown flag;
    ``fallback(item)`` takes requests the arena can never hold.  Setting
    ``draining`` makes the loop exit once its queue/intake and live rows
    are served out — the zero-loss scale-down path: the owner must simply
    stop feeding the queue first.
    """

    def __init__(self, server: LMServer, *, index: int, queue, arrived,
                 stats: BatcherStats, cpu, is_closed, fallback=None,
                 max_batch: int = 8, quantum: int = 8, prompt_cap: int = 64,
                 prefix_tokens: int = 1 << 16, arena_cap: int | None = None,
                 lease_ttl_s: float = 60.0, role: str = "unified",
                 handoff=None, intake=None, paged: bool = False,
                 block_size: int = 16, prefill_budget: int | None = None,
                 pool_blocks: int | None = None, recover=None,
                 heartbeat: bool = True):
        if role not in ("unified", "prefill", "decode"):
            raise ValueError(f"unknown engine-loop role {role!r}")
        if role == "prefill" and handoff is None:
            raise ValueError("a prefill-role loop needs a handoff callback")
        if paged and role != "unified":
            # row migration moves contiguous cache rows; a paged row is a
            # table of shared refcounted blocks with no standalone payload
            raise ValueError("paged arenas serve role='unified' only "
                             "(block tables cannot migrate between pools)")
        self.server = server
        self.index = index
        self.queue = queue
        self.intake = intake if intake is not None else deque()
        self.arrived = arrived
        self.stats = stats
        self.cpu = cpu
        self.is_closed = is_closed
        self.fallback = fallback
        self.role = role
        self.handoff = handoff
        # ``recover(item)`` re-queues a row lost to worker/state failure
        # for replay somewhere else (the fleet router re-routes around the
        # dead member); default = this loop's own queue.
        self.recover = recover
        self.heartbeat = bool(heartbeat)
        self.draining = False
        self.engine = None                     # set once run() starts
        self.live: dict[int, _LiveRow] = {}
        self.pending: dict[int, _LiveRow] = {}  # paged: prefill in flight
        self._free: deque[int] = deque()
        # paged: slots evicted locally but not yet released worker-side —
        # shipped as ``free_slots`` on the next engine call so blocks are
        # always given back BEFORE a slot id can be re-admitted
        self._to_free: set[int] = set()
        # per-member accounting the fleet router/bench report
        self.served = 0
        self.chunks = 0
        self.chunk_occupancy = 0
        self.migrated_in = 0
        self.migrated_out = 0
        self._root_span = obs_trace.NOOP       # set for real in run()
        self._kwargs = dict(rows=max(1, max_batch),
                            prompt_cap=prompt_cap, quantum=quantum,
                            prefix_tokens=prefix_tokens, ttl_s=lease_ttl_s,
                            cap=arena_cap, paged=paged, block_size=block_size,
                            prefill_budget=prefill_budget,
                            pool_blocks=pool_blocks)

    # -------------------------------------------------------- router view --
    @property
    def rows(self) -> int:
        return self._kwargs["rows"]

    @property
    def free_rows(self) -> int:
        return self.rows - len(self.live) - len(self.pending)

    @property
    def load(self) -> int:
        """Row-units of work this member owns (queued + live + pending +
        in-flight hand-offs) — what the router's least-loaded policies
        compare."""
        pend = sum(1 for _, f in self.queue if not f.done())
        return pend + len(self.live) + len(self.pending) + len(self.intake)

    @property
    def closing(self) -> bool:
        return self.draining or self.is_closed()

    # ---------------------------------------------------------- internals --
    def _prune(self) -> None:
        while self.queue and self.queue[0][1].done():
            self.queue.popleft()               # cancelled while queued
        while self.intake and self.intake[0]["row"].fut.done():
            self.intake.popleft()

    def _fail(self, fut: asyncio.Future, e: BaseException,
              what: str) -> None:
        if not fut.done():
            fut.set_exception(e if isinstance(e, Exception)
                              else RuntimeError(f"{what}: {e!r}"))
        self.stats.requests += 1

    def _complete_row(self, row: _LiveRow, now: float) -> None:
        times = row.token_times_ms[:row.request.max_new]
        if not row.fut.done():
            row.fut.set_result(Completion(
                tokens=[int(t) for t in row.tokens[:row.request.max_new]],
                latency_ms=(now - row.t_arrival) * 1000.0,
                ttft_ms=row.ttft_ms, cost_gb_s=row.cost_gb_s,
                token_times_ms=times or None,
                recovered=row.recovered))
        self.stats.requests += 1
        self.served += 1
        _M_DONE.inc()
        _M_TTFT.observe(row.ttft_ms)
        if len(times) > 1:
            _M_TPOT.observe((times[-1] - times[0]) / (len(times) - 1))

    # ------------------------------------------------------- failover ----
    @staticmethod
    def _replayable(err: BaseException) -> bool:
        """Infrastructure loss — worker death, dropped connection, expired
        lease — is replayable; user-code/model errors are not (replaying a
        deterministic failure would just fail again elsewhere)."""
        from ..runtime.engine import is_state_lost
        return (is_state_lost(err) or isinstance(err, WorkerCrash)
                or isinstance(err, ConnectionError))

    def _recover_item(self, item) -> None:
        if self.recover is not None:
            self.recover(item)
        else:
            self.queue.append(item)
            self.arrived.set()

    def _readmit_ok(self, fut) -> bool:
        """Bounded requeue for a request whose ADMISSION died (no tokens
        lost — it never entered the arena)."""
        n = getattr(fut, "_readmits", 0) + 1
        fut._readmits = n
        return n <= MAX_ROW_REPLAYS

    def _try_replay(self, row: _LiveRow, err: BaseException) -> bool:
        """Requeue a lost live/pending row as ``prompt + generated_so_far``
        for chunked re-prefill on a healthy arena.  Greedy decode is a
        pure function of the token prefix, so the recovered completion is
        bit-identical to the unfailed one — worker death becomes added
        latency, not a client-visible error.  Returns False when the row
        must fail instead (non-replayable error, replay cap reached)."""
        fut = row.fut
        if fut.done() or not self._replayable(err) \
                or row.replays >= MAX_ROW_REPLAYS:
            return False
        orig = row.request
        fut._replay = {"request": orig,
                       "tokens": [int(t) for t in row.tokens],
                       "t_arrival": row.t_arrival, "ttft_ms": row.ttft_ms,
                       "token_times_ms": list(row.token_times_ms),
                       "cost": row.cost_gb_s, "attempts": row.replays + 1}
        replay = Request(
            prompt=list(orig.prompt) + [int(t) for t in row.tokens],
            max_new=row.remaining)
        self._recover_item((replay, fut))
        return True

    def _resume_row(self, meta: dict, fut, t0: int, now: float,
                    share: float = 0.0) -> _LiveRow:
        """Rebuild a replayed row at re-admission: original request, prior
        tokens + the re-prefill's first continuation token, timing merged
        so ``token_times_ms[0] == ttft_ms`` still holds."""
        t_ms = (now - meta["t_arrival"]) * 1000.0
        row = _LiveRow(request=meta["request"], fut=fut,
                       t_arrival=meta["t_arrival"],
                       tokens=list(meta["tokens"]) + [int(t0)],
                       ttft_ms=meta["ttft_ms"],
                       cost_gb_s=meta["cost"] + share,
                       token_times_ms=list(meta["token_times_ms"]) + [t_ms],
                       recovered=True, replays=meta["attempts"])
        return row

    def _lose_state(self, err: BaseException) -> None:
        recovered = failed = 0
        now = asyncio.get_running_loop().time()
        for rows in (self.live, self.pending):
            for slot, row in rows.items():
                self._free.append(slot)
                if row.fut.done():
                    continue
                if row.remaining <= 0:
                    # every requested token already arrived client-side:
                    # the crash cost nothing — deliver
                    self._complete_row(row, now)
                elif self._try_replay(row, err):
                    recovered += 1
                else:
                    self._fail(row.fut, err, "engine failed")
                    failed += 1
            rows.clear()
        self._to_free.clear()      # the new handle starts with a fresh pool
        self.engine.reset()
        self.stats.state_resets += 1
        if recovered:
            self.stats.recovered_rows += recovered
            _M_RECOVERED.inc(recovered)
            rspan = self._span("engine.recover_rows", rows=recovered,
                               failed=failed, error=type(err).__name__)
            rspan.finish()

    def _span(self, name: str, **attrs):
        """A child span under this loop's root trace (NOOP when tracing is
        off or this loop's root was sampled out)."""
        root = self._root_span
        if not root:
            return obs_trace.NOOP
        return obs_trace.TRACER.span(name, root.ctx, **attrs)

    def _bound(self, span, fn):
        """Bind ``span`` as the dispatch parent for ``fn`` when it runs on
        the pack executor thread: the client.submit span the engine call
        mints over there nests under this chunk's span."""
        return obs_trace.bound(span.ctx, fn) if span else fn

    # --------------------------------------------------------------- run --
    async def run(self) -> None:
        from ..runtime.engine import EngineClient, is_state_lost
        loop = asyncio.get_running_loop()
        self._root_span = (obs_trace.TRACER.start_trace(
            "engine.loop", member=self.index, role=self.role)
            if obs_trace.TRACER.enabled else obs_trace.NOOP)
        try:
            # affinity = member/loop index, deterministically: a warmup
            # pass and the run it warms land on the SAME workers (a global
            # counter would re-home every fresh loop onto cold slots)
            self.engine = engine = EngineClient(self.server,
                                               affinity=self.index,
                                               **self._kwargs)
        except BaseException as e:
            # a loop that dies before serving must not leave submitters
            # parked forever: fail whatever is queued and surface the error
            while self.queue:
                _, fut = self.queue.popleft()
                self._fail(fut, e, "engine init failed")
            while self.intake:
                self._fail(self.intake.popleft()["row"].fut, e,
                           "engine init failed")
            raise
        live = self.live
        free = self._free
        free.extend(range(engine.rows))
        hits_seen = misses_seen = 0
        if self.heartbeat:
            # lease renewal decoupled from engine calls: a stalled loop
            # (chaos straggle, long pack) cannot expire live rows' state
            engine.start_heartbeat()

        try:
            while True:
                self._prune()
                # ---------------------------------- admission (every chunk)
                if self.role == "decode":
                    await self._admit_migrated(loop, is_state_lost)
                else:
                    await self._admit_prompts(loop, is_state_lost)
                # paged: advance in-flight chunked prefills by one budget's
                # worth of tokens, so long prompts interleave with the
                # decode chunk below instead of stalling it
                if self.pending:
                    await self._advance_prefill(loop, is_state_lost)
                # fold this engine's prefix-mirror counters into the shared
                # stats as deltas (several engine loops share one stats)
                self.stats.prefix_hits += engine.prefix_hits - hits_seen
                self.stats.prefix_misses += engine.prefix_misses - misses_seen
                hits_seen = engine.prefix_hits
                misses_seen = engine.prefix_misses

                # -------------------------------------- completion sweep
                now = loop.time()
                for slot in list(live):
                    row = live[slot]
                    if row.fut.done() or row.remaining <= 0:
                        self._complete_row(row, now)
                        del live[slot]
                        free.append(slot)
                        if engine.paged:
                            self._to_free.add(slot)
                for slot in list(self.pending):   # cancelled mid-prefill
                    if self.pending[slot].fut.done():
                        self._complete_row(self.pending.pop(slot), now)
                        free.append(slot)
                        self._to_free.add(slot)

                # ------------------------------------------ idle / close
                if not live:
                    waiting = (self.intake if self.role == "decode"
                               else self.queue)
                    if waiting or self.pending:
                        continue        # free slots / prefill work remain
                    if self.closing:
                        return
                    self.arrived.clear()
                    if waiting or self.closing:
                        continue
                    await self.arrived.wait()
                    continue

                # -------------------------------------------- decode chunk
                k = engine.choose_k(max(row.remaining
                                        for row in live.values()))
                if engine.paged:
                    # paged slots release by refcount drop, exactly once
                    # per eviction (a pending slot's blocks must survive)
                    idle = tuple(self._to_free)
                else:
                    # free every non-live slot, not just freshly-evicted
                    # ones: an idle freed slot whose start stayed at its
                    # freeze-time value would pin arena compaction forever
                    idle = tuple(s for s in range(engine.rows)
                                 if s not in live)
                cspan = self._span("engine.decode_quantum", k=k,
                                   rows=len(live))
                try:
                    inv_fut = await loop.run_in_executor(
                        self.cpu, self._bound(cspan, engine.submit_step),
                        k, idle)
                    reply = engine.observe(await await_invocation(inv_fut))
                except BaseException as e:
                    cspan.set("error.type", type(e).__name__)
                    cspan.finish("error")
                    self._lose_state(e)
                    if isinstance(e, asyncio.CancelledError):
                        raise
                    continue
                cspan.finish()
                self._to_free.difference_update(idle)
                self._note_occupancy()
                toks = reply["tokens"]
                rec = inv_fut.record
                share = (rec.billed_gb_s / len(live)) if rec else 0.0
                # ONE stamping point for per-token times: every token this
                # chunk delivered arrived, client-side, at this reply
                # (serve_bench and the TPOT metrics consume these stamps
                # instead of re-deriving from latency - ttft)
                t_chunk = loop.time()
                for slot, row in live.items():
                    need = row.remaining
                    if need > 0:
                        new = [int(t) for t in toks[slot][:need]]
                        row.tokens.extend(new)
                        t_ms = (t_chunk - row.t_arrival) * 1000.0
                        row.token_times_ms.extend([t_ms] * len(new))
                    row.cost_gb_s += share
                self.stats.decode_chunks += 1
                self.stats.decode_steps += k
                self.stats.occupancy_sum += len(live)
                _M_CHUNKS.inc()
                self.chunks += 1
                self.chunk_occupancy += len(live)
        finally:
            self._root_span.set("served", self.served)
            self._root_span.set("chunks", self.chunks)
            self._root_span.finish()
            await loop.run_in_executor(self.cpu, engine.close)

    # ---------------------------------------------------------- admission --
    async def _admit_prompts(self, loop, is_state_lost) -> None:
        """Unified/prefill admission: pop queued prompts into free slots,
        one prefill round-trip; prefill-role loops then extract and hand
        the finished rows off instead of keeping them live."""
        engine, live, free = self.engine, self.live, self._free
        take: list[tuple[int, Request, asyncio.Future]] = []
        while free and self.queue:
            r, fut = self.queue.popleft()
            if fut.done():
                continue
            if not engine.fits(len(r.prompt), r.max_new):
                if self.fallback is not None:
                    self.fallback((r, fut))
                else:
                    self._fail(fut, ValueError(
                        f"prompt of {len(r.prompt)} tokens cannot fit this "
                        "arena and no fallback is configured"), "admission")
                continue
            take.append((free.popleft(), r, fut))
        if engine.paged:
            await self._admit_paged(loop, is_state_lost, take)
            return
        if not take:
            return
        t_sent = loop.time()
        hits0, miss0 = engine.prefix_hits, engine.prefix_misses
        pspan = self._span("engine.prefill", rows=len(take))
        try:
            inv_fut, order = await loop.run_in_executor(
                self.cpu, self._bound(pspan, engine.submit_admit),
                [(slot, r.prompt) for slot, r, _ in take],
                # an arena holding live rows must already exist: never
                # silently recreate an expired lease under them
                not live)
            reply = engine.observe(await await_invocation(inv_fut))
        except BaseException as e:
            pspan.set("error.type", type(e).__name__)
            pspan.finish("error")
            for slot, r, fut in take:
                free.append(slot)
                # infrastructure loss during admission: nothing was decoded
                # yet, so the request (or in-flight replay) simply requeues
                if not fut.done() and self._replayable(e) \
                        and not isinstance(e, asyncio.CancelledError) \
                        and self._readmit_ok(fut):
                    self._recover_item((r, fut))
                else:
                    self._fail(fut, e, "admission failed")
            if is_state_lost(e):
                self._lose_state(e)
            if isinstance(e, asyncio.CancelledError):
                raise
            return
        pspan.set("prefix_hits", engine.prefix_hits - hits0)
        pspan.set("prefix_misses", engine.prefix_misses - miss0)
        pspan.finish()
        now = loop.time()
        rec = inv_fut.record
        share = (rec.billed_gb_s / len(take)) if rec else 0.0
        ttft = (now - t_sent) * 1000.0
        by_slot = {slot: (r, fut) for slot, r, fut in take}
        for slot, t0 in zip(order, reply["first"]):
            r, fut = by_slot[slot]
            meta = getattr(fut, "_replay", None)
            if meta is not None:
                # this admission was a failover re-prefill: resume the
                # original row where its dead arena left off
                del fut._replay
                live[slot] = self._resume_row(meta, fut, t0, now, share)
                continue
            live[slot] = _LiveRow(request=r, fut=fut, t_arrival=t_sent,
                                  tokens=[int(t0)], ttft_ms=ttft,
                                  cost_gb_s=share,
                                  token_times_ms=[ttft])
        self.stats.admission_groups += 1
        if self.role == "prefill":
            await self._handoff_rows(loop, list(live), is_state_lost)

    # ------------------------------------------------- paged admission --
    def _promote(self, reply: dict, now: float, share: float = 0.0) -> None:
        """Move pending rows whose chunked prefill just completed into the
        live set, stamping TTFT at the reply that produced their first
        token (not at admission — a long prompt's TTFT includes every
        chunk it waited through)."""
        for slot, info in reply.get("slots", {}).items():
            row = self.pending.get(int(slot))
            if row is None:
                continue
            row.cost_gb_s += share
            if info.get("live"):
                del self.pending[int(slot)]
                row.tokens.append(int(info["first"]))
                t_ms = (now - row.t_arrival) * 1000.0
                if row.recovered and row.token_times_ms:
                    # failover re-prefill: TTFT was stamped by the original
                    # admission — this is just the next token arriving late
                    row.token_times_ms.append(t_ms)
                else:
                    row.ttft_ms = t_ms
                    row.token_times_ms.append(t_ms)
                self.live[int(slot)] = row

    def _note_occupancy(self) -> None:
        occ = self.engine.occupancy
        if not occ:
            return
        st = self.stats
        st.live_tokens_peak = max(st.live_tokens_peak,
                                  int(occ.get("live_tokens", 0)))
        st.allocated_blocks_peak = max(st.allocated_blocks_peak,
                                       int(occ.get("allocated_blocks", 0)))
        st.shared_blocks_peak = max(st.shared_blocks_peak,
                                    int(occ.get("shared_blocks", 0)))

    async def _admit_paged(self, loop, is_state_lost, take) -> None:
        """Paged admission: one prefill round-trip admits the new rows and
        advances them up to the chunk budget.  Rows that finish inside the
        call go live with their first token; the rest stay pending and
        advance via :meth:`_advance_prefill` on later iterations."""
        engine, live, free = self.engine, self.live, self._free
        if not take:
            return
        t_sent = loop.time()
        hits0, miss0 = engine.prefix_hits, engine.prefix_misses
        pspan = self._span("engine.prefill_chunk", rows=len(take))
        try:
            inv_fut, _ = await loop.run_in_executor(
                self.cpu, self._bound(pspan, engine.submit_admit),
                [(slot, r.prompt) for slot, r, _ in take],
                not (live or self.pending), tuple(self._to_free))
            reply = engine.observe_paged_prefill(
                await await_invocation(inv_fut))
        except BaseException as e:
            pspan.set("error.type", type(e).__name__)
            pspan.finish("error")
            for slot, r, fut in take:
                free.append(slot)
                if not fut.done() and self._replayable(e) \
                        and not isinstance(e, asyncio.CancelledError) \
                        and self._readmit_ok(fut):
                    self._recover_item((r, fut))
                else:
                    self._fail(fut, e, "admission failed")
            if is_state_lost(e):
                self._lose_state(e)
            if isinstance(e, asyncio.CancelledError):
                raise
            return
        pspan.set("radix_hits", engine.prefix_hits - hits0)
        pspan.set("radix_misses", engine.prefix_misses - miss0)
        pspan.finish()
        self._to_free.clear()
        now = loop.time()
        rec = inv_fut.record
        share = (rec.billed_gb_s / len(take)) if rec else 0.0
        for slot, r, fut in take:
            meta = getattr(fut, "_replay", None)
            if meta is not None:
                # failover re-prefill joins the pending set carrying its
                # prior tokens; _promote appends the continuation token
                # without restamping TTFT
                del fut._replay
                self.pending[slot] = _LiveRow(
                    request=meta["request"], fut=fut,
                    t_arrival=meta["t_arrival"],
                    tokens=list(meta["tokens"]), ttft_ms=meta["ttft_ms"],
                    cost_gb_s=meta["cost"],
                    token_times_ms=list(meta["token_times_ms"]),
                    recovered=True, replays=meta["attempts"])
                continue
            self.pending[slot] = _LiveRow(request=r, fut=fut,
                                          t_arrival=t_sent)
        self._promote(reply, now, share)
        self.stats.admission_groups += 1
        self._note_occupancy()

    async def _advance_prefill(self, loop, is_state_lost) -> None:
        """One budget's worth of chunked-prefill progress for the pending
        rows (no new admissions).  Any failure here is arena-fatal — the
        pool's block accounting is mid-flight — so it resets like a failed
        decode chunk."""
        engine = self.engine
        pspan = self._span("engine.prefill_chunk", pending=len(self.pending))
        try:
            inv_fut = await loop.run_in_executor(
                self.cpu, self._bound(pspan, engine.submit_prefill_step),
                tuple(self._to_free))
            reply = engine.observe_paged_prefill(
                await await_invocation(inv_fut))
        except BaseException as e:
            pspan.set("error.type", type(e).__name__)
            pspan.finish("error")
            self._lose_state(e)
            if isinstance(e, asyncio.CancelledError):
                raise
            return
        pspan.finish()
        self._to_free.clear()
        rec = inv_fut.record
        n = max(1, len(self.pending))
        share = (rec.billed_gb_s / n) if rec else 0.0
        self._promote(reply, loop.time(), share)
        self.stats.admission_groups += 1
        self._note_occupancy()

    async def _handoff_rows(self, loop, slots, is_state_lost) -> None:
        """Prefill role: pull the freshly-prefilled rows out of the arena
        (freeing its slots for the next admission group) and hand them to
        the router, which places them in a decode member's intake.  TTFT
        was already stamped at the prefill reply — migration latency shows
        up in per-token time, not time-to-first-token."""
        engine, live, free = self.engine, self.live, self._free
        mspan = self._span("engine.migrate_out", rows=len(slots))
        try:
            payloads = await loop.run_in_executor(
                self.cpu, self._bound(mspan, engine.extract_rows), slots)
        except BaseException as e:
            mspan.set("error.type", type(e).__name__)
            mspan.finish("error")
            for slot in slots:
                row = live.pop(slot, None)
                if row is not None:
                    self._fail(row.fut, e, "row hand-off failed")
                free.append(slot)
            if is_state_lost(e):
                engine.reset()
                self.stats.state_resets += 1
            if isinstance(e, asyncio.CancelledError):
                raise
            return
        mspan.finish()
        items = []
        for slot, payload in zip(slots, payloads):
            row = live.pop(slot)
            free.append(slot)
            items.append({"entry": payload, "row": row})
        self.migrated_out += len(items)
        self.stats.migrated_rows += len(items)
        await self.handoff(items)

    async def _admit_migrated(self, loop, is_state_lost) -> None:
        """Decode-role admission: insert migrated rows from the intake into
        free slots.  An idle decode arena may have expired between bursts —
        when no rows are live it is (re)built empty first, so an insert can
        never silently target a blank lease."""
        engine, live, free = self.engine, self.live, self._free
        take: list[tuple[int, dict]] = []
        while free and self.intake:
            ent = self.intake.popleft()
            if ent["row"].fut.done():
                continue
            take.append((free.popleft(), ent))
        if not take:
            return
        slots = [slot for slot, _ in take]
        mspan = self._span("engine.migrate_in", rows=len(take))
        try:
            if not live:
                inv_fut, _ = await loop.run_in_executor(
                    self.cpu, self._bound(mspan, engine.submit_admit),
                    [], True)
                engine.observe(await await_invocation(inv_fut))
            await loop.run_in_executor(
                self.cpu, self._bound(mspan, engine.insert_rows), slots,
                [ent["entry"] for _, ent in take])
        except BaseException as e:
            mspan.set("error.type", type(e).__name__)
            mspan.finish("error")
            for slot, ent in take:
                free.append(slot)
                self._fail(ent["row"].fut, e, "row insert failed")
            if is_state_lost(e):
                self._lose_state(e)
            if isinstance(e, asyncio.CancelledError):
                raise
            return
        mspan.finish()
        for slot, ent in take:
            live[slot] = ent["row"]
        self.migrated_in += len(take)
        self.stats.admission_groups += 1


def _merge_replay(fut, comp: Completion, now: float) -> Completion:
    """Fold a failover re-prefill served by the WAVE path back into its
    original request's completion: a replay whose grown prompt exceeded
    ``prompt_cap`` falls back to a solo wave, which decodes only the
    continuation — prepend the tokens decoded before the crash and keep
    the original TTFT/arrival timing (ISSUE 10)."""
    meta = getattr(fut, "_replay", None)
    if meta is None:
        return comp
    del fut._replay
    orig = meta["request"]
    tokens = list(meta["tokens"]) + [int(t) for t in comp.tokens]
    t_ms = (now - meta["t_arrival"]) * 1000.0
    times = list(meta["token_times_ms"]) + \
        [t_ms] * max(0, len(tokens) - len(meta["token_times_ms"]))
    n = orig.max_new
    return Completion(tokens=tokens[:n], latency_ms=t_ms,
                      cost_gb_s=meta["cost"] + comp.cost_gb_s,
                      ttft_ms=meta["ttft_ms"],
                      token_times_ms=times[:n] or None, recovered=True)


class ContinuousBatcher:
    """Admit arriving requests into in-flight decode capacity.

    ::

        async with ContinuousBatcher(server, max_batch=8, slots=4,
                                     max_wait_ms=10) as batcher:
            completion = await batcher.submit(Request(prompt, max_new=16))

    ``submit`` may be called from any number of concurrent tasks; each
    returns when *its* request completes.  Cancelling the awaiting task
    removes a still-queued request from the scheduler (a request already
    admitted runs on; its slot is reclaimed at the next chunk boundary and
    its result dropped).

    Iteration-level knobs (ignored on the batch-level path): ``quantum``
    decode steps per chunk (admission/eviction granularity), ``prompt_cap``
    longest admissible prompt (longer ones fall back to a solo wave),
    ``prefix_tokens`` budget of the worker-resident prompt-prefix cache
    (LRU by token count; 0 disables), ``arena_cap`` cache capacity
    override, ``lease_ttl_s`` the worker-side state lease.

    Paged knobs (ISSUE 7): ``paged=True`` swaps each slot arena for a
    refcounted block-pool KV arena — prompts above ``prompt_cap`` no
    longer fall back to solo waves (prefill is chunked under
    ``prefill_budget`` tokens per engine call), and the prefix store
    becomes a radix index whose shared prefixes share physical blocks.
    ``block_size`` is the KV block granularity (rounded to a power of
    two), ``pool_blocks`` overrides the pool size.  Ignored on families
    without a paged layout (ssm serves from the slot arena, which already
    admits any prompt length) and on the batch-level path.
    """

    def __init__(self, server: LMServer, *, max_batch: int = 8,
                 slots: int = 2, max_wait_ms: float = 10.0,
                 iteration_level: bool | None = None, quantum: int = 8,
                 prompt_cap: int = 64, prefix_tokens: int = 1 << 16,
                 arena_cap: int | None = None, lease_ttl_s: float = 60.0,
                 paged: bool = False, block_size: int = 16,
                 prefill_budget: int | None = None,
                 pool_blocks: int | None = None, heartbeat: bool = True):
        self._server = server
        self._heartbeat = bool(heartbeat)
        self._max_batch = max(1, max_batch)
        self._n_slots = max(1, slots)
        self._max_wait_s = max(0.0, max_wait_ms) / 1000.0
        self._iteration = iteration_level
        self._quantum = max(1, quantum)
        self._prompt_cap = max(1, prompt_cap)
        self._prefix_tokens = max(0, prefix_tokens)
        self._arena_cap = arena_cap
        self._lease_ttl_s = lease_ttl_s
        self._paged = bool(paged)
        self._block_size = max(1, block_size)
        self._prefill_budget = prefill_budget
        self._pool_blocks = pool_blocks
        self._queue: deque[tuple[Request, asyncio.Future]] = deque()
        self._slots: asyncio.Semaphore | None = None
        self._arrived: asyncio.Event | None = None
        self._scheduler: asyncio.Task | None = None
        self._loops: list[asyncio.Task] = []
        self._batch_tasks: set[asyncio.Task] = set()
        self._closed = False
        # ONE pack/unpack thread, deliberately: payload serialization is
        # GIL-bound python — fanning it across executor threads only adds
        # contention that stretches every in-flight roundtrip.  Transport
        # IO still overlaps across all slots (iteration-level submits
        # return futures immediately; only packing serializes here).
        self._cpu = ThreadPoolExecutor(max_workers=1,
                                       thread_name_prefix="repro-batcher")
        self.stats = BatcherStats()

    # ------------------------------------------------------------ lifecycle
    def _resolve_mode(self) -> bool:
        if self._iteration is not False:
            # auto OR forced-on: both require a resident-state backend and
            # an arena-capable family — a forced-on batcher on e.g. encdec
            # demotes to batch-level rather than wedging every submit
            # behind an engine that cannot be constructed
            from ..models.api import arena_supported
            caps = self._server.session.backend.capabilities
            self._iteration = bool(getattr(caps, "resident_state", False)) \
                and arena_supported(self._server.cfg)
        return bool(self._iteration)

    def _ensure_running(self) -> None:
        running = (self._loops if self._resolve_mode()
                   else (self._scheduler is not None
                         and not self._scheduler.done()))
        if running:
            return
        if self._closed:
            raise RuntimeError("batcher is closed")
        loop = asyncio.get_running_loop()
        self._slots = self._slots or asyncio.Semaphore(self._n_slots)
        self._arrived = self._arrived or asyncio.Event()
        if self._iteration:
            self.stats.mode = "iteration"
            self._loops = [loop.create_task(self._engine_loop(i))
                           for i in range(self._n_slots)]
        else:
            self.stats.mode = "batch"
            self._scheduler = loop.create_task(self._schedule())

    async def __aenter__(self) -> "ContinuousBatcher":
        self._ensure_running()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Stop admitting, let in-flight work finish, fail queued requests
        that never made it into a batch/arena."""
        self._closed = True
        if self._arrived is not None:
            self._arrived.set()
        if self._scheduler is not None:
            await self._scheduler
        if self._loops:
            await asyncio.gather(*self._loops, return_exceptions=True)
        if self._batch_tasks:
            await asyncio.gather(*self._batch_tasks, return_exceptions=True)
        while self._queue:
            _, fut = self._queue.popleft()
            if not fut.done():
                fut.set_exception(RuntimeError("batcher closed before the "
                                               "request was scheduled"))
        self._cpu.shutdown(wait=False)

    # ------------------------------------------------------------- clients
    async def submit(self, request: Request) -> Completion:
        """Queue one request; resolves when its decode completes."""
        if self._closed:
            raise RuntimeError("batcher is closed")
        self._ensure_running()
        fut = asyncio.get_running_loop().create_future()
        self._queue.append((request, fut))
        self._arrived.set()
        return await fut

    @property
    def queued(self) -> int:
        return sum(1 for _, f in self._queue if not f.done())

    @property
    def iteration_level(self) -> bool:
        """Which granularity this batcher runs at (resolved lazily)."""
        return self._resolve_mode()

    # ----------------------------------------------------------- scheduler
    def _prune(self) -> None:
        while self._queue and self._queue[0][1].done():
            self._queue.popleft()            # cancelled while queued

    # ======================================================== batch-level =
    def _batch_ready(self) -> bool:
        """A batch can seal without waiting: the head's bucket alone fills
        it, or the whole queue does (top-up keeps the slot busy)."""
        self._prune()
        if not self._queue:
            return False
        b = decode_bucket(self._queue[0][0].max_new)
        live = head = 0
        for r, f in self._queue:
            if f.done():
                continue
            live += 1
            head += decode_bucket(r.max_new) == b
        return head >= self._max_batch or live >= self._max_batch

    def _take_batch(self) -> list[tuple[Request, asyncio.Future]]:
        """Seal a batch: FIFO head defines the preferred decode bucket;
        take up to ``max_batch`` live requests from that bucket first, then
        top up with the oldest other-bucket requests.  Bucketing is a
        *preference*, not a constraint: a pure batch decodes short, a
        topped-up batch decodes at its longest member (what a fixed wave
        would have done anyway) — so grouping can only save compute, never
        idle a free slot behind it.
        """
        self._prune()
        if not self._queue:
            return []
        bucket = decode_bucket(self._queue[0][0].max_new)
        batch: list[tuple[Request, asyncio.Future]] = []
        keep: deque = deque()
        while self._queue:                   # pass 1: the head's bucket
            r, f = self._queue.popleft()
            if f.done():
                continue
            if len(batch) < self._max_batch and \
                    decode_bucket(r.max_new) == bucket:
                batch.append((r, f))
            else:
                keep.append((r, f))
        while keep and len(batch) < self._max_batch:   # pass 2: top up
            batch.append(keep.popleft())
        self._queue.extend(keep)             # leftovers keep arrival order
        return batch

    async def _schedule(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._prune()
            if not self._queue:
                if self._closed:
                    return
                self._arrived.clear()
                if self._queue:              # raced an append
                    continue
                await self._arrived.wait()
                continue
            await self._slots.acquire()
            # a slot is ours: give the forming batch up to max_wait to fill
            sealed_by = "full"
            if not self._batch_ready() and self._max_wait_s > 0 \
                    and not self._closed:
                deadline = loop.time() + self._max_wait_s
                while not self._batch_ready() and not self._closed:
                    remaining = deadline - loop.time()
                    if remaining <= 0:
                        sealed_by = "wait"
                        break
                    self._arrived.clear()
                    try:
                        await asyncio.wait_for(self._arrived.wait(), remaining)
                    except asyncio.TimeoutError:
                        sealed_by = "wait"
                        break
            batch = self._take_batch()
            if not batch:
                self._slots.release()
                continue
            if sealed_by == "full":
                self.stats.sealed_full += 1
            else:
                self.stats.sealed_wait += 1
            task = loop.create_task(self._run_batch(batch))
            self._batch_tasks.add(task)
            task.add_done_callback(self._batch_tasks.discard)

    async def _run_batch(self,
                         batch: list[tuple[Request, asyncio.Future]],
                         *, hold_slot: bool = True) -> None:
        loop = asyncio.get_running_loop()
        requests = [r for r, _ in batch]
        bucket = decode_bucket(max(r.max_new for r in requests))
        try:
            # payload packing ships params: keep it off the loop.  min_rows
            # pins the batch-shape bucket so partial batches never compile
            # a fresh entry point mid-serve.
            inv_fut = await loop.run_in_executor(
                self._cpu, lambda: self._server.submit_wave(
                    requests, min_rows=self._max_batch))
            await await_invocation(inv_fut)
            comps = await loop.run_in_executor(
                self._cpu, self._server.unpack_wave, requests, inv_fut)
        except BaseException as e:
            for _, fut in batch:
                if not fut.done():
                    fut.set_exception(
                        e if isinstance(e, Exception)
                        else RuntimeError(f"batch failed: {e!r}"))
        else:
            t_done = loop.time()
            for (_, fut), comp in zip(batch, comps):
                if not fut.done():
                    fut.set_result(_merge_replay(fut, comp, t_done))
        finally:
            self.stats.requests += len(batch)
            self.stats.batches += 1
            self.stats.occupancy_sum += len(batch)
            self.stats.decode_steps += bucket
            self.stats.bucket_histogram[bucket] = \
                self.stats.bucket_histogram.get(bucket, 0) + 1
            if hold_slot:
                self._slots.release()

    # ==================================================== iteration-level =
    def _fallback_wave(self, item: tuple[Request, asyncio.Future]) -> None:
        """A request the arena cannot hold (prompt above ``prompt_cap``):
        serve it as a solo wave so it is never silently starved."""
        self.stats.wave_fallbacks += 1
        task = asyncio.get_running_loop().create_task(
            self._run_batch([item], hold_slot=False))
        self._batch_tasks.add(task)
        task.add_done_callback(self._batch_tasks.discard)

    async def _engine_loop(self, index: int) -> None:
        """One worker-resident arena, driven step-chunk by step-chunk by a
        unified-role :class:`EngineLoop` over the batcher's shared queue:
        admit into free rows, decode ``k`` steps, evict finished rows,
        repeat.  Admission and eviction both happen at chunk boundaries —
        the iteration-level quantum."""
        await EngineLoop(
            self._server, index=index, queue=self._queue,
            arrived=self._arrived, stats=self.stats, cpu=self._cpu,
            is_closed=lambda: self._closed, fallback=self._fallback_wave,
            max_batch=self._max_batch, quantum=self._quantum,
            prompt_cap=self._prompt_cap, prefix_tokens=self._prefix_tokens,
            arena_cap=self._arena_cap, lease_ttl_s=self._lease_ttl_s,
            paged=self._paged, block_size=self._block_size,
            prefill_budget=self._prefill_budget,
            pool_blocks=self._pool_blocks,
            heartbeat=self._heartbeat).run()


def run_continuous(server: LMServer, requests: Sequence[Request], *,
                   concurrency: int = 16, max_batch: int = 8, slots: int = 2,
                   max_wait_ms: float = 10.0,
                   **batcher_kwargs) -> list[Completion]:
    """Closed-loop convenience driver: feed ``requests`` through a
    :class:`ContinuousBatcher` with at most ``concurrency`` outstanding;
    returns completions in request order.  This is what ``--mode
    continuous`` in the serve launcher/example runs.  Extra keyword
    arguments (``iteration_level``, ``quantum``, ``prefix_tokens``, …)
    pass through to the batcher.
    """
    async def go() -> list[Completion]:
        sem = asyncio.Semaphore(max(1, concurrency))
        async with ContinuousBatcher(server, max_batch=max_batch,
                                     slots=slots,
                                     max_wait_ms=max_wait_ms,
                                     **batcher_kwargs) as batcher:
            async def one(r: Request) -> Completion:
                async with sem:
                    return await batcher.submit(r)
            return list(await asyncio.gather(*[one(r) for r in requests]))
    return asyncio.run(go())
