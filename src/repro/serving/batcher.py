"""Continuous batching for :class:`~repro.runtime.server.LMServer` (ISSUE 3).

Wave mode pre-partitions requests into fixed batches and fork-joins them —
fine for offline bulk, wrong for traffic: a request arriving just after a
wave sealed waits a full wave, and every member of a wave decodes as far
as its longest neighbour.  The :class:`ContinuousBatcher` replaces the
fixed partition with *slot-based admission*:

* up to ``slots`` decode batches are in flight at once; the moment one
  completes, its slot is refilled from whatever has arrived since;
* a forming batch seals when it reaches ``max_batch`` requests or has
  waited ``max_wait_ms`` since its head request arrived — the classic
  throughput/latency knob pair;
* queued requests are grouped by decode-length bucket
  (:func:`~repro.runtime.server.decode_bucket`), so short generations are
  not packed behind long ones and only decode as far as they need.

Batches dispatch through the same ``submit_wave`` / ``unpack_wave`` core
as wave mode — same wire payloads, same per-request pro-rata billing —
so the two schedulers differ *only* in admission policy: packing is pad-
masked end to end (``pack_prompts`` lengths → prefill/decode masks), so a
request decodes to the same greedy tokens whichever scheduler ran it and
whatever ragged company it was batched with.

Granularity note: each batch is one stateless serverless task, so
admission happens between batches (a request cannot join a decode loop
already running on a worker).  That is the serverless analogue of
iteration-level continuous batching: the admission quantum is one task,
not one decode step.
"""
from __future__ import annotations

import asyncio
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Sequence

from ..runtime.server import Completion, LMServer, Request, decode_bucket
from .aio import await_invocation


@dataclass
class BatcherStats:
    """Scheduler-side accounting (client latency is measured by callers)."""
    requests: int = 0
    batches: int = 0
    occupancy_sum: int = 0           # sum of batch sizes
    decode_steps: int = 0            # sum of per-batch decode bucket lengths
    sealed_full: int = 0             # batches sealed by max_batch
    sealed_wait: int = 0             # batches sealed by max_wait
    bucket_histogram: dict = field(default_factory=dict)

    @property
    def mean_batch(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0

    def summary(self) -> dict:
        return {"requests": self.requests, "batches": self.batches,
                "mean_batch": round(self.mean_batch, 2),
                "decode_steps": self.decode_steps,
                "sealed_full": self.sealed_full,
                "sealed_wait": self.sealed_wait,
                "buckets": dict(sorted(self.bucket_histogram.items()))}


class ContinuousBatcher:
    """Admit arriving requests into in-flight decode capacity.

    ::

        async with ContinuousBatcher(server, max_batch=8, slots=4,
                                     max_wait_ms=10) as batcher:
            completion = await batcher.submit(Request(prompt, max_new=16))

    ``submit`` may be called from any number of concurrent tasks; each
    returns when *its* request's batch completes.  Cancelling the awaiting
    task removes a still-queued request from the scheduler (a request
    already packed into a dispatched batch runs to completion and is
    dropped at unpack).
    """

    def __init__(self, server: LMServer, *, max_batch: int = 8,
                 slots: int = 2, max_wait_ms: float = 10.0):
        self._server = server
        self._max_batch = max(1, max_batch)
        self._n_slots = max(1, slots)
        self._max_wait_s = max(0.0, max_wait_ms) / 1000.0
        self._queue: deque[tuple[Request, asyncio.Future]] = deque()
        self._slots: asyncio.Semaphore | None = None
        self._arrived: asyncio.Event | None = None
        self._scheduler: asyncio.Task | None = None
        self._batch_tasks: set[asyncio.Task] = set()
        self._closed = False
        # ONE pack/unpack thread, deliberately: payload serialization is
        # GIL-bound python — fanning it across executor threads only adds
        # contention that stretches every in-flight roundtrip.  Transport
        # IO still overlaps across all slots.
        self._cpu = ThreadPoolExecutor(max_workers=1,
                                       thread_name_prefix="repro-batcher")
        self.stats = BatcherStats()

    # ------------------------------------------------------------ lifecycle
    def _ensure_running(self) -> None:
        if self._scheduler is None or self._scheduler.done():
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._slots = self._slots or asyncio.Semaphore(self._n_slots)
            self._arrived = self._arrived or asyncio.Event()
            self._scheduler = asyncio.get_running_loop().create_task(
                self._schedule())

    async def __aenter__(self) -> "ContinuousBatcher":
        self._ensure_running()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Stop admitting, let in-flight batches finish, fail queued
        requests that never made it into a batch."""
        self._closed = True
        if self._arrived is not None:
            self._arrived.set()
        if self._scheduler is not None:
            await self._scheduler
        if self._batch_tasks:
            await asyncio.gather(*self._batch_tasks, return_exceptions=True)
        while self._queue:
            _, fut = self._queue.popleft()
            if not fut.done():
                fut.set_exception(RuntimeError("batcher closed before the "
                                               "request was scheduled"))
        self._cpu.shutdown(wait=False)

    # ------------------------------------------------------------- clients
    async def submit(self, request: Request) -> Completion:
        """Queue one request; resolves when its decode batch completes."""
        if self._closed:
            raise RuntimeError("batcher is closed")
        self._ensure_running()
        fut = asyncio.get_running_loop().create_future()
        self._queue.append((request, fut))
        self._arrived.set()
        return await fut

    @property
    def queued(self) -> int:
        return sum(1 for _, f in self._queue if not f.done())

    # ----------------------------------------------------------- scheduler
    def _prune(self) -> None:
        while self._queue and self._queue[0][1].done():
            self._queue.popleft()            # cancelled while queued

    def _batch_ready(self) -> bool:
        """A batch can seal without waiting: the head's bucket alone fills
        it, or the whole queue does (top-up keeps the slot busy)."""
        self._prune()
        if not self._queue:
            return False
        b = decode_bucket(self._queue[0][0].max_new)
        live = head = 0
        for r, f in self._queue:
            if f.done():
                continue
            live += 1
            head += decode_bucket(r.max_new) == b
        return head >= self._max_batch or live >= self._max_batch

    def _take_batch(self) -> list[tuple[Request, asyncio.Future]]:
        """Seal a batch: FIFO head defines the preferred decode bucket;
        take up to ``max_batch`` live requests from that bucket first, then
        top up with the oldest other-bucket requests.  Bucketing is a
        *preference*, not a constraint: a pure batch decodes short, a
        topped-up batch decodes at its longest member (what a fixed wave
        would have done anyway) — so grouping can only save compute, never
        idle a free slot behind it.
        """
        self._prune()
        if not self._queue:
            return []
        bucket = decode_bucket(self._queue[0][0].max_new)
        batch: list[tuple[Request, asyncio.Future]] = []
        keep: deque = deque()
        while self._queue:                   # pass 1: the head's bucket
            r, f = self._queue.popleft()
            if f.done():
                continue
            if len(batch) < self._max_batch and \
                    decode_bucket(r.max_new) == bucket:
                batch.append((r, f))
            else:
                keep.append((r, f))
        while keep and len(batch) < self._max_batch:   # pass 2: top up
            batch.append(keep.popleft())
        self._queue.extend(keep)             # leftovers keep arrival order
        return batch

    async def _schedule(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._prune()
            if not self._queue:
                if self._closed:
                    return
                self._arrived.clear()
                if self._queue:              # raced an append
                    continue
                await self._arrived.wait()
                continue
            await self._slots.acquire()
            # a slot is ours: give the forming batch up to max_wait to fill
            sealed_by = "full"
            if not self._batch_ready() and self._max_wait_s > 0 \
                    and not self._closed:
                deadline = loop.time() + self._max_wait_s
                while not self._batch_ready() and not self._closed:
                    remaining = deadline - loop.time()
                    if remaining <= 0:
                        sealed_by = "wait"
                        break
                    self._arrived.clear()
                    try:
                        await asyncio.wait_for(self._arrived.wait(), remaining)
                    except asyncio.TimeoutError:
                        sealed_by = "wait"
                        break
            batch = self._take_batch()
            if not batch:
                self._slots.release()
                continue
            if sealed_by == "full":
                self.stats.sealed_full += 1
            else:
                self.stats.sealed_wait += 1
            task = loop.create_task(self._run_batch(batch))
            self._batch_tasks.add(task)
            task.add_done_callback(self._batch_tasks.discard)

    async def _run_batch(self,
                         batch: list[tuple[Request, asyncio.Future]]) -> None:
        loop = asyncio.get_running_loop()
        requests = [r for r, _ in batch]
        bucket = decode_bucket(max(r.max_new for r in requests))
        try:
            # payload packing ships params: keep it off the loop.  min_rows
            # pins the batch-shape bucket so partial batches never compile
            # a fresh entry point mid-serve.
            inv_fut = await loop.run_in_executor(
                self._cpu, lambda: self._server.submit_wave(
                    requests, min_rows=self._max_batch))
            await await_invocation(inv_fut)
            comps = await loop.run_in_executor(
                self._cpu, self._server.unpack_wave, requests, inv_fut)
        except BaseException as e:
            for _, fut in batch:
                if not fut.done():
                    fut.set_exception(
                        e if isinstance(e, Exception)
                        else RuntimeError(f"batch failed: {e!r}"))
        else:
            for (_, fut), comp in zip(batch, comps):
                if not fut.done():
                    fut.set_result(comp)
        finally:
            self.stats.requests += len(batch)
            self.stats.batches += 1
            self.stats.occupancy_sum += len(batch)
            self.stats.decode_steps += bucket
            self.stats.bucket_histogram[bucket] = \
                self.stats.bucket_histogram.get(bucket, 0) + 1
            self._slots.release()


def run_continuous(server: LMServer, requests: Sequence[Request], *,
                   concurrency: int = 16, max_batch: int = 8, slots: int = 2,
                   max_wait_ms: float = 10.0) -> list[Completion]:
    """Closed-loop convenience driver: feed ``requests`` through a
    :class:`ContinuousBatcher` with at most ``concurrency`` outstanding;
    returns completions in request order.  This is what ``--mode
    continuous`` in the serve launcher/example runs.
    """
    async def go() -> list[Completion]:
        sem = asyncio.Semaphore(max(1, concurrency))
        async with ContinuousBatcher(server, max_batch=max_batch,
                                     slots=slots,
                                     max_wait_ms=max_wait_ms) as batcher:
            async def one(r: Request) -> Completion:
                async with sem:
                    return await batcher.submit(r)
            return list(await asyncio.gather(*[one(r) for r in requests]))
    return asyncio.run(go())
