"""Non-blocking multiplexed HTTP client + the ``"http-aio"`` backend.

The PR 2 ``http`` backend is the paper's client *model* but not its client
*shape*: a pool of blocking keep-alive connections, one OS thread parked
per in-flight request — concurrency caps at the thread budget.  The paper
drives hundreds of concurrent invocations from one client with a
conns × streams budget (16 × 100).  This module is that client, asyncio-
native:

* :class:`AioHttpClient` — a hand-rolled HTTP/1.1 client on asyncio
  streams.  ``n_connections`` persistent sockets are multiplexed from one
  event loop; ``streams_per_connection`` scales the admission budget
  (``conns × streams`` requests may be in flight/parked at once, the
  paper's stream budget applied to an HTTP/1.1 pool — each socket carries
  one request at a time, the budget bounds what may *wait* for one).
* :class:`AioHttpBackend` — the same worker model as ``HttpBackend``
  (spawned or ``url=``-external worker host, shared manifest, measured
  client-observed latency) but every request is driven by the async client
  on one background event loop: N invocations in flight cost N socket
  reads, not N blocked threads.  Registered as ``"http-aio"`` — a drop-in
  for sync ``Session`` *and* the natural floor under
  :class:`~repro.serving.aio.AsyncSession`.

Failure contract unchanged: connection loss or a dead worker surfaces as a
retryable ``WorkerCrash`` (the dispatcher's retry policy resubmits), never
a hung future.
"""
from __future__ import annotations

import asyncio
import threading
import time
from collections import deque
from typing import Any

from ..dispatch.futures import Invocation, InvocationRecord
from ..dispatch.transports import HttpBackend, _deliver, _worker_crash
from ..dispatch.workers import BackendCapabilities
from ..obs import trace as obs_trace
from ..serialization import wire


class _Conn:
    __slots__ = ("reader", "writer")

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self.reader = reader
        self.writer = writer

    def close(self) -> None:
        try:
            self.writer.close()
        except Exception:
            pass


class AioHttpClient:
    """Minimal asyncio HTTP/1.1 client with a persistent connection pool.

    ``await client.request(path, body)`` → response body bytes (raises
    ``ConnectionError`` on transport loss or non-200).  Connections are
    opened lazily up to ``n_connections``, reused keep-alive, and burned on
    any protocol error (the next request dials a fresh one).
    """

    def __init__(self, host: str, port: int, *, n_connections: int = 16,
                 streams_per_connection: int = 100,
                 request_timeout_s: float = 600.0):
        self.host = host
        self.port = port
        self.n_connections = max(1, n_connections)
        self.budget = self.n_connections * max(1, streams_per_connection)
        self._timeout = request_timeout_s
        self._free: deque[_Conn] = deque()
        self._n_open = 0
        self._conn_slots = asyncio.Semaphore(self.n_connections)
        self._budget_sem = asyncio.Semaphore(self.budget)
        self.inflight = 0               # admitted into the budget

    # ------------------------------------------------------------- wire
    async def _checkout(self) -> _Conn:
        while self._free:
            conn = self._free.popleft()
            if conn.writer.is_closing():
                self._n_open -= 1
                continue
            return conn
        reader, writer = await asyncio.open_connection(self.host, self.port)
        self._n_open += 1
        return _Conn(reader, writer)

    def _checkin(self, conn: _Conn) -> None:
        if conn.writer.is_closing():
            self._n_open -= 1
        else:
            self._free.append(conn)

    def _burn(self, conn: _Conn) -> None:
        conn.close()
        self._n_open -= 1

    async def _roundtrip(self, conn: _Conn, path: str, body: bytes) -> bytes:
        head = (f"POST {path} HTTP/1.1\r\n"
                f"Host: {self.host}:{self.port}\r\n"
                f"Content-Type: application/octet-stream\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: keep-alive\r\n\r\n").encode("ascii")
        conn.writer.write(head + body)
        await conn.writer.drain()
        status_line = await conn.reader.readline()
        if not status_line:
            raise ConnectionError("worker closed the connection")
        parts = status_line.split(None, 2)
        if len(parts) < 2 or not parts[0].startswith(b"HTTP/"):
            raise ConnectionError(f"malformed status line {status_line!r}")
        status = int(parts[1])
        headers: dict[str, str] = {}
        while True:
            line = await conn.reader.readline()
            if line in (b"\r\n", b"\n"):
                break
            if not line:
                raise ConnectionError("connection lost in response headers")
            k, _, v = line.decode("latin1").partition(":")
            headers[k.strip().lower()] = v.strip()
        reply = await conn.reader.readexactly(
            int(headers.get("content-length", 0)))
        if status != 200:
            raise ConnectionError(f"worker HTTP {status}")
        if headers.get("connection", "").lower() == "close":
            conn.writer.close()             # server refuses reuse
        return reply

    async def request(self, path: str, body: bytes) -> bytes:
        """One POST round-trip through the pooled client."""
        async with self._budget_sem:        # the conns × streams budget
            self.inflight += 1
            try:
                async with self._conn_slots:
                    conn = await self._checkout()
                    try:
                        reply = await asyncio.wait_for(
                            self._roundtrip(conn, path, body), self._timeout)
                    except BaseException:
                        self._burn(conn)
                        raise
                    self._checkin(conn)
                    return reply
            finally:
                self.inflight -= 1

    async def aclose(self) -> None:
        while self._free:
            self._burn(self._free.popleft())


# ----------------------------------------------------------------- backend

class AioHttpBackend(HttpBackend):
    """``"http-aio"``: the ``http`` worker model driven by one event loop.

    Same worker host, same wire protocol, same measured latency — but
    ``submit`` hands the invocation to a background event loop where the
    multiplexed :class:`AioHttpClient` drives it.  In-flight capacity is
    the client's conns × streams budget instead of a thread-pool size, so
    a sync ``Session`` gets paper-scale concurrency for free and an
    ``AsyncSession`` on top never blocks a thread at all.
    """

    # resident_state: one worker process serves every connection, so a
    # state handle is reachable on any of them (affinity is trivially
    # satisfied — same WorkerHost) and state CONTROL verbs ride the
    # inherited sync path
    capabilities = BackendCapabilities(concurrent=True, warm_reuse=True,
                                       measures_latency=True,
                                       cross_process=True,
                                       resident_state=True)

    def __init__(self, *, n_connections: int | None = None,
                 streams_per_connection: int = 100, os_threads: int = 16,
                 **kwargs: Any):
        super().__init__(os_threads=os_threads, n_connections=n_connections,
                         **kwargs)
        self._streams = max(1, streams_per_connection)
        self._loop: asyncio.AbstractEventLoop | None = None
        self._loop_thread: threading.Thread | None = None
        self._loop_lock = threading.Lock()
        self._client: AioHttpClient | None = None
        self._client_lock: asyncio.Lock | None = None
        self._pending = 0
        self._pending_lock = threading.Lock()

    # ------------------------------------------------------------ the loop
    def _ensure_loop(self) -> asyncio.AbstractEventLoop:
        with self._loop_lock:
            if self._loop is None:
                if self._stop:
                    raise RuntimeError("backend is shut down")
                self._loop = asyncio.new_event_loop()
                self._client_lock = asyncio.Lock()
                self._loop_thread = threading.Thread(
                    target=self._loop.run_forever,
                    name="repro-http-aio", daemon=True)
                self._loop_thread.start()
            return self._loop

    async def _ensure_client(self) -> AioHttpClient:
        async with self._client_lock:
            if self._client is None:
                # worker spawn blocks (subprocess + READY scrape): executor
                host, port = await asyncio.get_running_loop() \
                    .run_in_executor(None, self._ensure_worker)
                self._client = AioHttpClient(
                    host, port, n_connections=self._n_workers,
                    streams_per_connection=self._streams)
            return self._client

    # ------------------------------------------------------------- backend
    def submit(self, inv: Invocation) -> None:
        loop = self._ensure_loop()
        with self._pending_lock:
            self._pending += 1
        asyncio.run_coroutine_threadsafe(self._invoke(inv), loop)

    @property
    def queue_depth(self) -> int:
        return self._pending

    def scale_to(self, os_threads: int) -> None:
        if self._client is None:            # before first dial: grow the pool
            with self._lock:
                self._n_workers = max(self._n_workers, os_threads)

    async def _invoke(self, inv: Invocation) -> None:
        try:
            if inv.future.done():           # hedged sibling / cancelled
                return
            bridge = inv.deployed.bridge
            rec = InvocationRecord(
                task_id=inv.task_id, function_name=bridge.name,
                attempts=inv.attempt, hedged=inv.is_hedge,
                payload_bytes=len(inv.payload),
                memory_gb=bridge.config.memory_gb)
            ctx = inv.trace
            request = wire.encode_invoke(
                bridge.name, inv.payload,
                task_id=inv.task_id, attempt=inv.attempt,
                trace=ctx.to_wire() if ctx is not None else None,
                deadline=inv.deadline)
            tspan = (obs_trace.TRACER.span("client.transport", ctx,
                                           backend="AioHttpBackend")
                     if ctx is not None else obs_trace.NOOP)
            try:
                client = await self._ensure_client()
                t0 = time.perf_counter()
                reply = await client.request("/invoke", request)
                reply = await self._push_missing_artifacts(client, request,
                                                           reply)
                rec.modeled_latency_ms = (time.perf_counter() - t0) * 1000.0
                rec.latency_measured = True
            except Exception as e:
                detail = self._slot_epitaph(None) or \
                    (str(e) or type(e).__name__)
                tspan.set("error.type", type(e).__name__)
                tspan.set("error.detail", detail[:2000])
                tspan.finish("error")
                _deliver(inv, False,
                         _worker_crash(f"http-aio request failed "
                                       f"(task {inv.task_id}): {detail}"),
                         rec)
                return
            tspan.set("bytes_out", len(request))
            tspan.set("bytes_in", len(reply))
            tspan.finish()
            # reply decode + result deserialization are CPU-bound (payloads
            # can be params-sized): keep them off the event loop
            await asyncio.get_running_loop().run_in_executor(
                None, self._complete, inv, reply, rec)
        except BaseException as e:          # a backend bug must not hang futures
            inv.future.set_error(e)
        finally:
            with self._pending_lock:
                self._pending -= 1

    async def _push_missing_artifacts(self, client: AioHttpClient,
                                      request: bytes, reply: bytes) -> bytes:
        """Async twin of the sync transports' remote artifact fetch: push
        the blob the worker reported missing, replay the invocation."""
        from ..serialization.artifacts import export_artifact_blob
        loop = asyncio.get_running_loop()
        served: set[str] = set()
        while True:
            miss = wire.decode_artifact_missing(reply)
            if miss is None:
                return reply
            sha, path = miss
            if sha in served:
                return reply
            blob = await loop.run_in_executor(
                None, export_artifact_blob, sha, path)
            if blob is None:
                return reply
            ack = wire.decode(await client.request(
                "/invoke", wire.encode_control("artifact_put", body=blob,
                                               sha=sha)))
            if not (isinstance(ack, wire.ControlRequest)
                    and ack.data.get("ok")):
                return reply
            served.add(sha)
            reply = await client.request("/invoke", request)

    # ------------------------------------------------------------- control
    def drain_warm(self, function_name: str | None = None) -> int:
        if self._loop is None or self._client is None:
            return 0                        # nothing dialed, nothing warm
        frame = wire.encode_control("drain", function=function_name)

        async def go() -> bytes:
            client = await self._ensure_client()
            return await client.request("/invoke", frame)

        try:
            reply = asyncio.run_coroutine_threadsafe(
                go(), self._loop).result(timeout=30)
            msg = wire.decode(reply)
            if isinstance(msg, wire.ControlRequest):
                return int(msg.data.get("count", 0))
        except Exception:
            pass                            # a dead worker has nothing warm
        return 0

    def shutdown(self) -> None:
        self._stop = True
        with self._loop_lock:
            loop, self._loop = self._loop, None
            thread, self._loop_thread = self._loop_thread, None
        if loop is not None:
            client, self._client = self._client, None

            async def drain() -> None:
                # cancel in-flight invocations so their futures error out
                # (never hang) before the loop dies
                tasks = [t for t in asyncio.all_tasks()
                         if t is not asyncio.current_task()]
                for t in tasks:
                    t.cancel()
                await asyncio.gather(*tasks, return_exceptions=True)
                if client is not None:
                    await client.aclose()

            try:
                asyncio.run_coroutine_threadsafe(
                    drain(), loop).result(timeout=10)
            except Exception:
                pass
            loop.call_soon_threadsafe(loop.stop)
            if thread is not None:
                thread.join(timeout=5)
            loop.close()
        super().shutdown()                  # worker process + manifest file
