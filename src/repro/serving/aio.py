"""``AsyncSession`` — the asyncio-native serving facade (ISSUE 3).

The sync :class:`~repro.cloud.session.Session` is a fork-join client: one
blocking thread per ``result()`` waiter caps concurrency at the thread
budget.  The serving path wants the paper's client shape instead — hundreds
of invocations in flight from *one* event loop.  ``AsyncSession`` wraps any
registered backend and turns the session surface async::

    async with AsyncSession("http", max_inflight=64) as asess:
        f = asess.function(handler, memory_mb=512)
        out = await f.submit(x)                 # one invocation, awaited
        async for r in f.map_unordered(items):  # streaming fork-join
            ...
        inv = f.submit(x); inv.cancel()         # queued work really sheds

Three contracts make this work without polling:

* completions wake the loop through the thread-safe
  :meth:`~repro.dispatch.futures.InvocationFuture.add_done_callback`
  (fires exactly once, immediately if already done);
* the admission gate is *awaitable*: where the sync session raises
  :class:`~repro.cloud.session.Saturated` in shed mode, ``await
  asess.admit()`` parks the caller until inflight drains — backpressure
  without rejection and without a blocked thread;
* cancellation flows down: cancelling an :class:`AsyncInvocation` cancels
  the backend-level future, so still-queued work is skipped by every
  backend (they check ``future.done()`` before executing).

An ``AsyncSession`` binds to the first event loop that uses it; create one
per ``asyncio.run`` (wrapping a shared sync ``Session`` is cheap).
"""
from __future__ import annotations

import asyncio
import threading
import warnings
from collections import deque
from typing import Any, AsyncIterator, Callable, Iterable

from ..cloud.session import BoundFunction, Session, _as_args
from ..dispatch.futures import InvocationFuture, InvocationRecord


async def await_invocation(fut: InvocationFuture) -> Any:
    """Await a backend-level :class:`InvocationFuture` from a coroutine.

    The bridge primitive the whole subsystem stands on: the future's done
    callback (thread-safe, exactly-once) hands completion to the event loop
    via ``call_soon_threadsafe`` — no polling thread, no busy wait.
    """
    loop = asyncio.get_running_loop()
    afut: asyncio.Future = loop.create_future()

    def on_done(f: InvocationFuture) -> None:
        def resolve() -> None:
            if afut.cancelled():
                return
            err = f.exception(timeout=0)
            if err is not None:
                afut.set_exception(err)
            else:
                afut.set_result(f.result(timeout=0))
        try:
            loop.call_soon_threadsafe(resolve)
        except RuntimeError:
            pass                    # loop already closed: session tear-down

    fut.add_done_callback(on_done)
    return await afut


class _AdmissionGate:
    """Awaitable admission slots with thread-safe release.

    ``acquire`` runs on the loop; ``release_threadsafe`` may be called from
    any backend thread (it trampolines onto the loop).  FIFO hand-off: a
    freed slot goes to the oldest live waiter, so a stream of short tasks
    cannot starve an early big one.
    """

    def __init__(self, limit: int, loop: asyncio.AbstractEventLoop):
        self._limit = limit
        self._loop = loop
        self._admitted = 0
        self._waiters: deque[asyncio.Future] = deque()

    @property
    def admitted(self) -> int:
        return self._admitted

    @property
    def waiting(self) -> int:
        return sum(1 for w in self._waiters if not w.done())

    async def acquire(self) -> None:
        if self._admitted < self._limit and not self.waiting:
            self._admitted += 1
            return
        w = self._loop.create_future()
        self._waiters.append(w)
        try:
            await w
        except asyncio.CancelledError:
            if w.done() and not w.cancelled():
                self.release()      # granted but abandoned: pass the slot on
            raise

    def release(self) -> None:
        """Loop-side release: hand the slot to the next live waiter."""
        while self._waiters:
            w = self._waiters.popleft()
            if not w.done():
                w.set_result(None)  # slot changes hands; _admitted unchanged
                return
        self._admitted -= 1

    def release_threadsafe(self) -> None:
        try:
            self._loop.call_soon_threadsafe(self.release)
        except RuntimeError:
            pass                    # loop closed mid-completion


class AsyncInvocation:
    """Handle for one in-flight async invocation — awaitable + cancellable.

    ``await inv`` yields the result (or raises).  ``inv.cancel()``
    cancels the driving task *and* the backend-level future, so queued
    work is shed; a task already executing runs to completion but its
    result is dropped.  ``inv.record`` exposes the invocation record once
    resolved (cancelled invocations have none).
    """

    def __init__(self) -> None:
        self._task: asyncio.Task | None = None   # set by AsyncSession._submit
        self._fut: InvocationFuture | None = None
        self._abandoned = False

    def __await__(self):
        return self._task.__await__()

    def cancel(self) -> bool:
        self._abandoned = True
        if self._fut is not None:
            self._fut.cancel()
        return self._task.cancel()

    def done(self) -> bool:
        return self._task.done()

    def result(self) -> Any:
        return self._task.result()

    @property
    def future(self) -> InvocationFuture | None:
        """The backend-level future, once dispatched."""
        return self._fut

    @property
    def record(self) -> InvocationRecord | None:
        return self._fut.record if self._fut is not None else None


class AsyncBoundFunction:
    """Async twin of :class:`~repro.cloud.session.BoundFunction`.

    Same single-source property: ``f(x)`` is a plain local call; ``submit``
    returns an awaitable :class:`AsyncInvocation`; ``map_unordered`` is an
    async generator yielding results in completion order.
    """

    def __init__(self, asession: "AsyncSession", bound: BoundFunction):
        self._asession = asession
        self._bound = bound

    @property
    def name(self) -> str:
        return self._bound.name

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self._bound(*args, **kwargs)        # local call, untouched

    def options(self, **overrides: Any) -> "AsyncBoundFunction":
        return AsyncBoundFunction(self._asession,
                                  self._bound.options(**overrides))

    def submit(self, *args: Any, **kwargs: Any) -> AsyncInvocation:
        """Fire one invocation (admission-gated); must run inside the
        session's event loop."""
        return self._asession._submit(self._bound, args, kwargs)

    async def map_unordered(self, items: Iterable[Any], *,
                            timeout: float | None = None
                            ) -> AsyncIterator[Any]:
        """Streaming fork-join: ``async for r in f.map_unordered(items)``.

        All items are submitted eagerly (each one admission-gated); results
        stream back in completion order.  Closing the generator early (or
        a timeout) cancels the still-unfinished siblings.
        """
        invs = [self.submit(*_as_args(i)) for i in items]
        pending = {inv._task for inv in invs}
        deadline = None if timeout is None else \
            asyncio.get_running_loop().time() + timeout
        try:
            while pending:
                remaining = None
                if deadline is not None:
                    remaining = deadline - asyncio.get_running_loop().time()
                    if remaining <= 0:
                        raise TimeoutError("map_unordered() timed out")
                done, pending = await asyncio.wait(
                    pending, timeout=remaining,
                    return_when=asyncio.FIRST_COMPLETED)
                if not done:
                    raise TimeoutError("map_unordered() timed out")
                for t in done:
                    yield t.result()
        finally:
            for t in pending:
                t.cancel()

    def __repr__(self) -> str:
        return f"Async{self._bound!r}"


class AsyncSession:
    """Asyncio facade over a :class:`~repro.cloud.session.Session`.

    ``AsyncSession("http", os_threads=8)`` owns a fresh sync session (and
    closes it on ``aclose``/``__aexit__``); ``AsyncSession(existing_session)``
    wraps a caller-owned one.  ``max_inflight`` arms the awaitable
    admission gate: at most that many invocations in flight, further
    ``submit``/``admit`` callers park until completions free slots.
    """

    def __init__(self, backend: str | Session = "threads", *,
                 max_inflight: int | None = None, **session_kwargs: Any):
        if isinstance(backend, Session):
            if session_kwargs:
                raise TypeError("session kwargs only apply when AsyncSession "
                                "creates the session itself")
            self._session = backend
            self._owns = False
        else:
            self._session = Session(backend, **session_kwargs)
            self._owns = True
        self._max_inflight = max_inflight
        self._loop: asyncio.AbstractEventLoop | None = None
        self._gate: _AdmissionGate | None = None

    # ------------------------------------------------------------- binding
    def function(self, fn: Callable, **kwargs: Any) -> AsyncBoundFunction:
        """Bind ``fn`` into this async session (same kwargs as
        ``Session.function``)."""
        bound = self._session.function(fn, **kwargs)
        # RF4xx surface early, at bind time: a coroutine entry point or a
        # time.sleep inside one is an *async-session* mistake, and the
        # deploy-time pass only runs at first submit.  Bytecode-only check
        # (analyze_code) — no capture probing on the bind path.
        try:
            code = getattr(bound._rf.fn, "__code__", None)
            if code is not None:
                from ..analysis import ShippabilityWarning, analyze_code
                rf4 = [d for d in
                       analyze_code(code,
                                    module=getattr(fn, "__module__", None),
                                    qualname=bound.name)
                       if d.code.startswith("RF4")]
                if rf4:
                    lines = "\n".join("  " + d.format() for d in rf4)
                    warnings.warn(
                        f"async-session analysis of {bound.name!r} found "
                        f"{len(rf4)} issue(s):\n{lines}",
                        ShippabilityWarning, stacklevel=2)
        except Exception:
            pass
        return AsyncBoundFunction(self, bound)

    def remote(self, fn: Callable | None = None, **kwargs: Any):
        """Decorator form: ``@asess.remote`` / ``@asess.remote(memory_mb=...)``."""
        def wrap(f):
            return self.function(f, **kwargs)
        return wrap(fn) if fn is not None else wrap

    # ----------------------------------------------------- admission gate
    def _bind_loop(self) -> asyncio.AbstractEventLoop:
        loop = asyncio.get_running_loop()
        if self._loop is None:
            self._loop = loop
            if self._max_inflight is not None:
                self._gate = _AdmissionGate(self._max_inflight, loop)
        elif self._loop is not loop:
            raise RuntimeError(
                "AsyncSession is bound to a different event loop; create "
                "one AsyncSession per loop (wrapping a shared Session is "
                "cheap)")
        return loop

    async def admit(self, n: int = 1) -> None:
        """Park until ``n`` admission slots are free, then hold them.

        The awaitable counterpart of shed-mode: where ``Session(shed=True)``
        raises :class:`Saturated`, this waits for inflight to drain.  Slots
        acquired here must be paired with :meth:`release` (``submit`` does
        its own pairing internally).  No-op when ``max_inflight`` is unset.
        """
        self._bind_loop()
        if self._gate is None:
            return
        for _ in range(n):
            await self._gate.acquire()

    def release(self, n: int = 1) -> None:
        """Return ``n`` slots taken via :meth:`admit`."""
        if self._gate is not None:
            for _ in range(n):
                self._gate.release()

    @property
    def admitted(self) -> int:
        """Slots currently held (0 when the gate is unarmed)."""
        return self._gate.admitted if self._gate is not None else 0

    @property
    def waiting(self) -> int:
        """Callers parked in :meth:`admit` right now."""
        return self._gate.waiting if self._gate is not None else 0

    # ------------------------------------------------------------ dispatch
    def _submit(self, bound: BoundFunction, args: tuple,
                kwargs: dict) -> AsyncInvocation:
        loop = self._bind_loop()
        ainv = AsyncInvocation()
        ainv._task = loop.create_task(self._run(bound, args, kwargs, ainv))
        return ainv

    async def _run(self, bound: BoundFunction, args: tuple, kwargs: dict,
                   ainv: AsyncInvocation) -> Any:
        loop = self._loop
        gate = self._gate
        if gate is not None:
            await gate.acquire()
        started = threading.Event()

        def do_submit() -> InvocationFuture:
            # runs on an executor thread: payload packing (params-sized for
            # LM serving) must not stall the event loop.
            started.set()
            f = bound.submit(*args, **kwargs)
            if gate is not None:
                # the slot frees when the INVOCATION resolves, not when the
                # awaiting task is torn down — exactly once either way
                f.add_done_callback(lambda _f: gate.release_threadsafe())
            ainv._fut = f
            if ainv._abandoned:     # cancelled while packing: shed if queued
                f.cancel()
            return f

        try:
            inv_fut = await loop.run_in_executor(None, do_submit)
        except asyncio.CancelledError:
            ainv._abandoned = True
            f = ainv._fut
            if f is not None:
                f.cancel()          # release rides f's done callback
            elif not started.is_set():
                # executor never ran do_submit: the slot is still ours
                if gate is not None:
                    gate.release()
            # else: do_submit is mid-flight; it observes _abandoned and the
            # release callback it attaches fires when the future settles
            raise
        except BaseException:
            if gate is not None:
                gate.release()      # submit failed: nothing owns the slot
            raise
        try:
            return await await_invocation(inv_fut)
        except asyncio.CancelledError:
            inv_fut.cancel()        # queued work sheds; running work is dropped
            raise

    # ------------------------------------------------------------ plumbing
    @property
    def session(self) -> Session:
        return self._session

    @property
    def inflight(self) -> int:
        return self._session.inflight

    def close(self) -> None:
        if self._owns:
            self._session.close()

    async def aclose(self) -> None:
        """Close the owned sync session without blocking the loop (backend
        shutdown joins worker processes/threads)."""
        if self._owns:
            await asyncio.get_running_loop().run_in_executor(
                None, self._session.close)

    async def __aenter__(self) -> "AsyncSession":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def __repr__(self) -> str:
        gate = (f"max_inflight={self._max_inflight}"
                if self._max_inflight is not None else "ungated")
        return f"AsyncSession({self._session!r}, {gate})"
