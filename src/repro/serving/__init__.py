"""repro.serving — the asyncio-native serving stack (ISSUE 3).

Layered on the PR 2 runtime: :class:`AsyncSession` turns any registered
backend's session surface async (``await f.submit``, ``async for`` over
``map_unordered``, cancellation, awaitable admission gate);
:class:`AioHttpClient`/:class:`AioHttpBackend` (registered as
``"http-aio"``) drive the ``http`` worker model from one event loop with a
paper-style conns × streams budget; :class:`ContinuousBatcher` admits
arriving LM requests into in-flight decode capacity instead of fixed
waves.

    from repro.serving import AsyncSession, ContinuousBatcher

    async with AsyncSession("http-aio", max_inflight=64) as asess:
        f = asess.function(handler)
        out = await f.submit(x)
"""
from .aio import (AsyncBoundFunction, AsyncInvocation, AsyncSession,
                  await_invocation)
from .batcher import (BatcherStats, ContinuousBatcher, EngineLoop,
                      run_continuous)
from .http_client import AioHttpBackend, AioHttpClient

__all__ = [
    "AsyncSession", "AsyncBoundFunction", "AsyncInvocation",
    "await_invocation", "ContinuousBatcher", "BatcherStats", "EngineLoop",
    "run_continuous", "AioHttpClient", "AioHttpBackend",
]
