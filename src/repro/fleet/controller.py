"""Elastic fleet controller: queue depth in, scale events out.

Runs as one asyncio task next to the router (started by
``FleetRouter(elastic=True)``), sampling the fleet every ``interval_s``:

* **grow** when the backlog (queued or migrating rows) exceeds the free
  decode slots fleet-wide — the signal that adding a member converts
  queue wait into parallel decode — up to ``max_members``;
* **drain** the least-loaded member after ``patience`` consecutive
  samples of decode-slot occupancy below ``shrink_occupancy`` with an
  empty backlog, down to ``min_members`` (and never below one member of
  each role in disaggregated mode — the router's ``drain`` refuses).

Scale-down is always a cooperative drain: the member leaves the routing
set immediately, serves out everything it owns, then releases its state
lease.  Workers are never killed — a drained member's worker keeps its
warm sandboxes, so a later grow pays a warm start, which is the whole
point of scaling the *fleet* rather than the process pool.  Cold/warm
evidence for each event lives in ``Session.stats()`` (sandbox cold-start
and busy-time counters), sampled by the benchmark after the run — the
controller itself only reads client-side state, because backend stats are
blocking round-trips that must not run on the event loop.
"""
from __future__ import annotations

import asyncio

__all__ = ["FleetController"]


class FleetController:
    """Grow/shrink policy over a :class:`~repro.fleet.router.FleetRouter`.

    ``grow_cooldown_s`` spaces grows out so one backlog spike does not
    instantly fan out to ``max_members`` before the first new member had
    a chance to absorb anything.
    """

    def __init__(self, router, *, max_members: int, min_members: int = 1,
                 interval_s: float = 0.01, shrink_occupancy: float = 0.25,
                 patience: int = 5, grow_cooldown_s: float = 0.05):
        self.router = router
        self.max_members = max(1, max_members)
        self.min_members = max(1, min_members)
        self.interval_s = max(1e-3, interval_s)
        self.shrink_occupancy = shrink_occupancy
        self.patience = max(1, patience)
        self.grow_cooldown_s = grow_cooldown_s
        self._low_samples = 0
        self._last_grow = float("-inf")

    # one sample → at most one action; factored out so tests can drive the
    # policy synchronously without the timer task
    def step(self, now: float) -> str | None:
        r = self.router
        # reap: a member whose task finished while it was NOT draining
        # died (loop crashed / cancelled) — replace it so the pool holds
        # its size; its orphaned queue moves to the replacement
        for m in list(r.members):
            if getattr(m, "done", False) and not m.loop.draining \
                    and not getattr(m, "reaped", False):
                if r.respawn(m) is not None:
                    return "respawn"
        active = r.active_members
        if not active:
            return None
        backlog = r.backlog
        free = sum(m.loop.free_rows for m in active)
        rows = sum(m.loop.rows for m in active)
        live = rows - free
        if (backlog > free and len(active) < self.max_members
                and now - self._last_grow >= self.grow_cooldown_s):
            self._low_samples = 0
            self._last_grow = now
            r.grow(reason=f"backlog={backlog} free_rows={free}")
            return "grow"
        if backlog == 0 and rows and live / rows < self.shrink_occupancy \
                and len(active) > self.min_members:
            self._low_samples += 1
            if self._low_samples >= self.patience:
                self._low_samples = 0
                if r.drain(reason=f"occupancy={live}/{rows} for "
                                  f"{self.patience} samples") is not None:
                    return "drain"
            return None
        self._low_samples = 0
        return None

    async def run(self) -> None:
        loop = asyncio.get_running_loop()
        while not self.router._closed:
            await asyncio.sleep(self.interval_s)
            if self.router._closed:
                return
            try:
                self.step(loop.time())
            except RuntimeError:
                return                  # router closed under us
