"""repro.fleet — fleet serving on top of the iteration-level runtime.

One :class:`~repro.serving.batcher.EngineLoop` per fleet member, each
pinned (``FunctionConfig.affinity``) to its own worker with its own
resident cache arena, behind a :class:`FleetRouter`:

* **prefix-aware routing** — a client-side content-hash index over each
  member's resident prefix-cache mirror sends shared-prefix traffic to
  the member whose worker already holds it, falling back to least-loaded
  power-of-two-choices;
* **disaggregated prefill/decode** — an optional role split where
  prefill members admit prompts, extract the finished rows and migrate
  them (CONTROL frames, ``cache_extract_rows``/``cache_insert_rows``)
  into a decode member's arena;
* **elastic scaling** — a :class:`FleetController` grows the pool from
  queue backlog and drains (never kills) the least-loaded member on
  sustained low decode-slot occupancy; a draining member serves out its
  queue and live rows, so scale-down loses zero in-flight requests.

    from repro.fleet import run_fleet
    comps, fleet = run_fleet(server, requests, n_members=3,
                             policy="prefix", return_stats=True)
"""
from .controller import FleetController
from .router import FleetMember, FleetRouter, FleetStats, run_fleet

__all__ = ["FleetController", "FleetMember", "FleetRouter", "FleetStats",
           "run_fleet"]
