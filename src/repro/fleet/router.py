"""Prefix-aware fleet router over per-member iteration-level engine loops.

Each :class:`FleetMember` wraps one
:class:`~repro.serving.batcher.EngineLoop` with its own request queue and
a unique, never-reused affinity index, so every member is pinned to its
own worker (resident arena + prompt-prefix store).  The router owns
placement:

* ``policy="prefix"`` — a content-hash index (``prefix_key`` over the
  first ``prefix_len`` prompt tokens; the whole prompt when unset —
  exactly the key the worker-resident prefix store uses) remembers which
  member first served each prefix and routes repeats back to it, the
  client-side mirror of the workers' prefix caches.  An owner loaded past
  ``spill_factor × rows`` spills to power-of-two-choices *without*
  reassigning ownership — transient overload must not thrash affinity.
* ``policy="p2c"`` — least-loaded of two random members (the classic
  balanced-allocations bound on max load).
* ``policy="random"`` — uniform; the A/B baseline for prefix routing.
* ``policy="radix"`` — a client-side radix index over block-aligned
  token runs (the router's mirror of the workers' paged radix stores,
  ISSUE 7): a prompt routes to the member owning its *longest shared
  prefix*, so partial overlaps — not just exact repeats — land where the
  shared blocks already live.  Spill semantics match ``prefix``; both
  count as prefix-routed in the stats.

``disaggregate=True`` splits roles: prompts route only to prefill
members, whose freshly-prefilled rows migrate through ``handoff`` into
the least-loaded decode member's intake.  ``elastic=True`` starts a
:class:`~repro.fleet.controller.FleetController` that grows the pool
toward ``n_members`` under backlog and drains it back on sustained low
occupancy.  Draining never kills a worker: the member stops receiving
traffic, serves out its queue and live rows, then releases its lease —
its worker stays warm for the next grow.
"""
from __future__ import annotations

import asyncio
import random
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Sequence

from ..dispatch.retry import CircuitBreaker
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..runtime.engine import prefix_key
from ..runtime.server import Completion, LMServer, Request
from ..serving.aio import await_invocation
from ..serving.batcher import BatcherStats, EngineLoop

__all__ = ["FleetMember", "FleetRouter", "FleetStats", "run_fleet"]

# registry mirrors of the FleetStats fields — same numbers, uniform
# names/labels next to the client transport and engine-loop metrics
_M_ROUTED = obs_metrics.REGISTRY.counter(
    "fleet_routed_total", "requests placed on a fleet member")
_M_SCALE = obs_metrics.REGISTRY.counter(
    "fleet_scale_events_total", "elastic grow/drain decisions")
_M_HANDOFF = obs_metrics.REGISTRY.counter(
    "fleet_handoffs_total", "prefill→decode migration groups")


@dataclass
class FleetStats:
    """Router-side placement accounting (engine-side counters — prefix
    hits, chunks, migrations — live in the shared ``BatcherStats`` and
    per-member ``EngineLoop`` counters)."""
    routed_prefix: int = 0          # placed by the content-hash index
    routed_p2c: int = 0             # least-loaded fallback / p2c policy
    routed_random: int = 0
    spills: int = 0                 # owner over spill threshold
    handoffs: int = 0               # prefill→decode migration groups
    recoveries: int = 0             # rows re-routed after member failure
    scale_events: list = field(default_factory=list)

    @property
    def routed_total(self) -> int:
        return self.routed_prefix + self.routed_p2c + self.routed_random

    @property
    def prefix_route_rate(self) -> float:
        n = self.routed_total
        return self.routed_prefix / n if n else 0.0


class FleetMember:
    """One fleet member: an engine loop, its queue, and its task."""

    def __init__(self, index: int, role: str, loop: EngineLoop,
                 breaker: CircuitBreaker | None = None):
        self.index = index          # == the loop's worker affinity
        self.role = role
        self.loop = loop
        self.task: asyncio.Task | None = None
        # per-member circuit breaker: a row replayed off this member
        # records a failure; an open breaker takes the member out of the
        # routing set until the cooldown admits a half-open probe
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self.reaped = False         # controller already replaced it

    @property
    def active(self) -> bool:
        """Routable: running and not being drained."""
        return (self.task is not None and not self.task.done()
                and not self.loop.draining)

    @property
    def done(self) -> bool:
        return self.task is not None and self.task.done()

    def summary(self) -> dict:
        lp = self.loop
        return {"index": self.index, "role": self.role,
                "served": lp.served, "chunks": lp.chunks,
                "mean_occupancy": round(lp.chunk_occupancy / lp.chunks, 2)
                if lp.chunks else 0.0,
                "migrated_in": lp.migrated_in,
                "migrated_out": lp.migrated_out,
                "draining": lp.draining, "done": self.done,
                "breaker": self.breaker.snapshot()}


class FleetRouter:
    """Async router fronting a fleet of engine-loop members.

    ::

        async with FleetRouter(server, n_members=3) as fleet:
            completion = await fleet.submit(Request(prompt, max_new=16))

    Requires a resident-state backend and an arena-capable model family
    (the same contract as iteration-level ``ContinuousBatcher``); there
    is no batch-level demotion here — a fleet without worker-resident
    arenas is just N copies of the wave scheduler.
    """

    POLICIES = ("prefix", "p2c", "random", "radix")

    def __init__(self, server: LMServer, *, n_members: int = 3,
                 policy: str = "prefix", prefix_len: int | None = None,
                 spill_factor: float = 2.0, disaggregate: bool = False,
                 prefill_members: int = 1, elastic: bool = False,
                 min_members: int = 1, controller: dict | None = None,
                 max_batch: int = 8, quantum: int = 8, prompt_cap: int = 64,
                 prefix_tokens: int = 1 << 16, arena_cap: int | None = None,
                 lease_ttl_s: float = 60.0, seed: int = 0,
                 paged: bool = False, block_size: int = 16,
                 prefill_budget: int | None = None,
                 pool_blocks: int | None = None,
                 breaker: dict | None = None,
                 heartbeat: bool = True):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}")
        if paged and disaggregate:
            raise ValueError("paged arenas cannot disaggregate: block "
                             "tables do not migrate between pools")
        from ..models.api import arena_supported
        caps = server.session.backend.capabilities
        if not getattr(caps, "resident_state", False):
            raise ValueError(
                "fleet serving needs a resident-state backend "
                "(inline/threads/processes/http/http-aio) — "
                f"{type(server.session.backend).__name__} keeps none")
        if not arena_supported(server.cfg):
            raise ValueError(f"family {server.cfg.family!r} has no slot "
                             "arena; fleet serving is iteration-level only")
        self._server = server
        self.n_members = max(1, n_members)
        self.policy = policy
        self.prefix_len = prefix_len
        self.spill_factor = max(1.0, spill_factor)
        self.disaggregate = bool(disaggregate)
        self.prefill_members = max(1, prefill_members)
        self.elastic = bool(elastic)
        self.min_members = max(1, min_members)
        self._controller_kw = dict(controller or {})
        self._loop_kw = dict(max_batch=max_batch, quantum=quantum,
                             prompt_cap=prompt_cap,
                             prefix_tokens=prefix_tokens,
                             arena_cap=arena_cap, lease_ttl_s=lease_ttl_s,
                             paged=paged, block_size=block_size,
                             prefill_budget=prefill_budget,
                             pool_blocks=pool_blocks, heartbeat=heartbeat)
        # a single crash is a strong signal for a pinned member — one
        # failure opens the breaker, the cooldown admits a probe, and a
        # quiet probe window closes it again without an explicit success
        self._breaker_kw = dict(threshold=1, cooldown_s=0.25,
                                probe_window_s=0.25)
        self._breaker_kw.update(breaker or {})
        self._rng = random.Random(seed)
        self.members: list[FleetMember] = []
        self._next_index = 0
        self._capacity = 0              # backend workers provisioned so far
        self._owners: dict[str, FleetMember] = {}   # prefix key -> member
        if policy == "radix":
            # the router's longest-shared-prefix mirror; payloads are
            # member indices, one per block-aligned run — same geometry as
            # the workers' radix stores so claims stay block-aligned
            from ..runtime.radix import RadixIndex
            from ..runtime.server import shape_bucket
            self._radix = RadixIndex(shape_bucket(max(1, block_size)),
                                     budget_tokens=max(1, prefix_tokens))
        self._arrived: asyncio.Event | None = None
        self._controller_task: asyncio.Task | None = None
        self._solo_tasks: set[asyncio.Task] = set()
        self._closed = False
        self._started = False
        # one pack/unpack thread shared by every member, same rationale as
        # ContinuousBatcher: payload packing is GIL-bound python, transport
        # IO overlaps across members regardless
        self._cpu = ThreadPoolExecutor(max_workers=1,
                                       thread_name_prefix="repro-fleet")
        self.batcher_stats = BatcherStats(mode="iteration")
        self.stats = FleetStats()
        self._root_span = obs_trace.NOOP

    def _event_span(self, name: str, **attrs) -> None:
        """Instant marker under the fleet root trace (grow/drain/handoff
        are routing-set *moments*, not intervals)."""
        root = self._root_span
        if root:
            obs_trace.TRACER.span_at(name, root.ctx, time.time(), 0.0,
                                     **attrs)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._started:
            return
        if self._closed:
            raise RuntimeError("fleet router is closed")
        self._started = True
        self._arrived = asyncio.Event()
        if obs_trace.TRACER.enabled:
            self._root_span = obs_trace.TRACER.start_trace(
                "fleet.serve", policy=self.policy,
                disaggregate=self.disaggregate, elastic=self.elastic)
        initial = self.min_members if self.elastic else self.n_members
        if self.disaggregate:
            initial = max(initial, 2)   # never fewer than one of each role
            n_pre = min(self.prefill_members, initial - 1)
            roles = ["prefill"] * n_pre + ["decode"] * (initial - n_pre)
        else:
            roles = ["unified"] * initial
        for role in roles:
            self._spawn(role)
        if self.elastic:
            from .controller import FleetController
            ctl = FleetController(self, max_members=self.n_members,
                                  min_members=self.min_members,
                                  **self._controller_kw)
            self._controller_task = asyncio.get_running_loop().create_task(
                ctl.run())

    async def __aenter__(self) -> "FleetRouter":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Stop routing, serve out every member, fail never-admitted
        leftovers.  Members exit via their normal idle/close path, so
        everything admitted or queued before close still completes."""
        self._closed = True
        if self._controller_task is not None:
            self._controller_task.cancel()
            try:
                await self._controller_task
            except (asyncio.CancelledError, Exception):
                pass
        if self._arrived is not None:
            self._arrived.set()
        tasks = [m.task for m in self.members if m.task is not None]
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
        if self._solo_tasks:
            await asyncio.gather(*self._solo_tasks, return_exceptions=True)
        if self._root_span:
            self._root_span.set("routed", self.stats.routed_total)
            self._root_span.set("scale_events",
                                len(self.stats.scale_events))
            self._root_span.finish()
            self._root_span = obs_trace.NOOP
        for m in self.members:
            for q in (m.loop.queue, m.loop.intake):
                while q:
                    item = q.popleft()
                    fut = item[1] if isinstance(item, tuple) \
                        else item["row"].fut
                    if not fut.done():
                        fut.set_exception(RuntimeError(
                            "fleet closed before the request was scheduled"))
        self._cpu.shutdown(wait=False)

    # ------------------------------------------------------------- members
    def _backend_workers(self) -> int:
        be = self._server.session.backend
        st = getattr(be, "stats", None)
        if callable(st):
            try:
                return int(st().get("n_workers", 1))
            except Exception:
                pass
        return 1

    def _ensure_capacity(self, n: int) -> None:
        """Grow (only) the backend's pinned-worker count so a new member's
        affinity freezes onto its own worker.  Never shrinks — scale-down
        is cooperative draining, the workers stay warm."""
        if self._capacity == 0:
            self._capacity = self._backend_workers()
        if n <= self._capacity:
            return
        scale = getattr(self._server.session.backend, "scale_to", None)
        if scale is not None:
            scale(n)
        self._capacity = n

    def _spawn(self, role: str) -> FleetMember:
        idx = self._next_index
        self._next_index += 1
        self._ensure_capacity(idx + 1)
        loop = EngineLoop(
            self._server, index=idx, queue=deque(), arrived=self._arrived,
            stats=self.batcher_stats, cpu=self._cpu,
            is_closed=lambda: self._closed, fallback=self._fallback_wave,
            role=role, handoff=self._handoff if role == "prefill" else None,
            recover=lambda item, i=idx: self._recover(i, item),
            **self._loop_kw)
        member = FleetMember(idx, role, loop,
                             breaker=CircuitBreaker(**self._breaker_kw))
        member.task = asyncio.get_running_loop().create_task(loop.run())
        self.members.append(member)
        return member

    @property
    def active_members(self) -> list[FleetMember]:
        return [m for m in self.members if m.active]

    def _routable(self) -> list[FleetMember]:
        pool = [m for m in self.members if m.active and m.role != "decode"]
        # breaker-open members sit out; if EVERY breaker is open the pool
        # wins over the breakers — refusing all traffic helps nobody, and
        # the transport respawns dead workers lazily anyway
        ok = [m for m in pool if m.breaker.allow()]
        return ok or pool

    def _decoders(self) -> list[FleetMember]:
        pool = [m for m in self.members if m.active and m.role == "decode"]
        ok = [m for m in pool if m.breaker.allow()]
        return ok or pool

    # ------------------------------------------------------------- scaling
    def record_event(self, action: str, member: FleetMember,
                     reason: str) -> None:
        self.stats.scale_events.append({
            "t": asyncio.get_running_loop().time(), "action": action,
            "member": member.index, "role": member.role, "reason": reason,
            "active": len(self.active_members),
            "queued": self.backlog})
        _M_SCALE.inc(action=action, role=member.role)
        self._event_span(f"fleet.{action}", member=member.index,
                         role=member.role, reason=reason)

    def grow(self, role: str | None = None,
             reason: str = "manual") -> FleetMember:
        """Add one member (cold worker → warm on first use)."""
        if self._closed:
            raise RuntimeError("fleet router is closed")
        if role is None:
            role = "unified"
            if self.disaggregate:
                intake = sum(len(m.loop.intake) for m in self.members)
                queued = sum(m.loop.load for m in self._routable())
                role = "decode" if intake >= queued else "prefill"
        member = self._spawn(role)
        self.record_event("grow", member, reason)
        return member

    def drain(self, member: FleetMember | None = None,
              reason: str = "manual") -> FleetMember | None:
        """Cooperatively retire one member: it leaves the routing set now,
        serves out everything it already owns, then releases its lease.
        Returns ``None`` when no member can be spared (pool at its role
        minimum) — the controller treats that as "don't shrink"."""
        pool = self.active_members
        if member is None:
            spare = [m for m in pool
                     if sum(1 for o in pool if o.role == m.role) > 1
                     or (not self.disaggregate and len(pool) > 1)]
            if not spare:
                return None
            member = min(spare, key=lambda m: (m.loop.load, -m.index))
        elif not member.active:
            return None
        member.loop.draining = True
        # owners pointing at it reroute lazily (owner not routable → reassign)
        self._arrived.set()
        self.record_event("drain", member, reason)
        return member

    def respawn(self, member: FleetMember,
                reason: str = "member died") -> FleetMember | None:
        """Replace a dead member with a fresh one of the same role and
        move its orphaned queue/intake onto the replacement.  The dead
        member's worker (if its process died too) respawns lazily in the
        transport on first use of its slot."""
        if self._closed:
            return None
        member.reaped = True
        repl = self._spawn(member.role)
        self.record_event("respawn", repl, reason)
        while member.loop.queue:
            repl.loop.queue.append(member.loop.queue.popleft())
        while member.loop.intake:
            repl.loop.intake.append(member.loop.intake.popleft())
        self._arrived.set()
        return repl

    # ------------------------------------------------------------ failover
    def _recover(self, index: int, item) -> None:
        """A member's engine loop lost a live row to a worker crash /
        state loss and replayed it (prompt + generated so far).  Record
        the failure on that member's breaker — taking it out of the
        routing set for the cooldown — and re-route the replay like any
        fresh request, which now lands on a surviving member."""
        member = next((m for m in self.members if m.index == index), None)
        if member is not None:
            member.breaker.record_failure()
            self.record_event("recover", member,
                              "row replayed after worker/state loss")
        self.stats.recoveries += 1
        request, fut = item
        if fut.done():
            return
        try:
            self.route(request, fut)
        except RuntimeError as e:
            fut.set_exception(e)

    # ------------------------------------------------------------- routing
    @property
    def backlog(self) -> int:
        """Queued-but-not-live request rows across the whole fleet."""
        n = 0
        for m in self.members:
            n += sum(1 for _, f in m.loop.queue if not f.done())
            n += len(m.loop.intake)
        return n

    def _p2c(self, targets: list[FleetMember]) -> FleetMember:
        if len(targets) == 1:
            return targets[0]
        a, b = self._rng.sample(targets, 2)
        return min((a, b), key=lambda m: (m.loop.load, m.index))

    def _radix_choose(self, prompt: Sequence[int],
                      targets: list[FleetMember]) -> tuple[FleetMember, str]:
        toks = [int(t) for t in prompt]
        h, owners = self._radix.match(toks)
        owner = None
        if h and owners:
            # deepest matched run names the member holding the most
            # shared blocks
            owner = next((m for m in targets if m.index == owners[-1]),
                         None)
        if owner is not None:
            if owner.loop.load < self.spill_factor * owner.loop.rows:
                return owner, "prefix"
            # transient overload spills to p2c WITHOUT reclaiming the
            # runs — same no-thrash rule as the "prefix" policy
            self.stats.spills += 1
            return self._p2c(targets), "p2c"
        member = self._p2c(targets)
        bs = self._radix.bs
        nb = (len(toks) // bs) * bs
        if nb:
            # claim this prompt's block-aligned head for the chosen member
            # (overwrite: traffic follows the freshest placement, and a
            # drained member's runs are reclaimed by the next claimant)
            self._radix.insert(toks[:nb], [member.index] * (nb // bs),
                               overwrite=True)
            self._radix.evict()
        return member, "p2c"

    def _choose(self, prompt: Sequence[int],
                targets: list[FleetMember]) -> tuple[FleetMember, str]:
        if self.policy == "random":
            return self._rng.choice(targets), "random"
        if self.policy == "p2c":
            return self._p2c(targets), "p2c"
        if self.policy == "radix":
            return self._radix_choose(prompt, targets)
        key = prefix_key(prompt[:self.prefix_len]
                         if self.prefix_len else prompt)
        owner = self._owners.get(key)
        if owner is not None and owner in targets:
            if owner.loop.load < self.spill_factor * owner.loop.rows:
                return owner, "prefix"
            self.stats.spills += 1
            return self._p2c(targets), "p2c"
        member = self._p2c(targets)
        self._owners[key] = member      # claim future traffic for this key
        return member, "p2c"

    def route(self, request: Request, fut: asyncio.Future) -> FleetMember:
        """Place one request on a member's queue (sync, event-loop side)."""
        targets = self._routable()
        if not targets:
            raise RuntimeError("fleet has no routable member "
                               "(all draining or done)")
        member, how = self._choose(request.prompt, targets)
        setattr(self.stats, f"routed_{how}",
                getattr(self.stats, f"routed_{how}") + 1)
        _M_ROUTED.inc(how=how, role=member.role)
        member.loop.queue.append((request, fut))
        self._arrived.set()
        return member

    async def submit(self, request: Request) -> Completion:
        """Route one request; resolves when its decode completes."""
        if self._closed:
            raise RuntimeError("fleet router is closed")
        self.start()
        fut = asyncio.get_running_loop().create_future()
        self.route(request, fut)
        return await fut

    # ------------------------------------------------------------ handoff
    async def _handoff(self, items: list[dict]) -> None:
        """Prefill→decode migration: place extracted rows in the least-
        loaded decode member's intake.  The payloads are client-side
        bytes, so a decode member lost between extract and insert costs a
        re-route, not the rows."""
        decs = self._decoders()
        if not decs:
            err = RuntimeError("no decode member available for hand-off")
            for ent in items:
                if not ent["row"].fut.done():
                    ent["row"].fut.set_exception(err)
                self.batcher_stats.requests += 1
            return
        member = min(decs, key=lambda m: (m.loop.load, m.index))
        member.loop.intake.extend(items)
        self.stats.handoffs += 1
        _M_HANDOFF.inc()
        self._event_span("fleet.handoff", rows=len(items),
                         to_member=member.index)
        self._arrived.set()

    # ------------------------------------------------------- solo fallback
    def _fallback_wave(self, item: tuple[Request, asyncio.Future]) -> None:
        """A request no arena can hold (prompt above ``prompt_cap``) is
        served as a solo wave so it is never silently starved."""
        self.batcher_stats.wave_fallbacks += 1
        task = asyncio.get_running_loop().create_task(self._run_solo(item))
        self._solo_tasks.add(task)
        task.add_done_callback(self._solo_tasks.discard)

    async def _run_solo(self, item: tuple[Request, asyncio.Future]) -> None:
        loop = asyncio.get_running_loop()
        r, fut = item
        try:
            inv_fut = await loop.run_in_executor(
                self._cpu, lambda: self._server.submit_wave([r]))
            await await_invocation(inv_fut)
            comps = await loop.run_in_executor(
                self._cpu, self._server.unpack_wave, [r], inv_fut)
        except BaseException as e:
            if not fut.done():
                fut.set_exception(e if isinstance(e, Exception)
                                  else RuntimeError(f"solo wave: {e!r}"))
            if isinstance(e, asyncio.CancelledError):
                raise
        else:
            if not fut.done():
                fut.set_result(comps[0])
        finally:
            self.batcher_stats.requests += 1

    # ------------------------------------------------------------- summary
    def summary(self) -> dict:
        st = self.stats
        return {
            "n_members": len(self.members),
            "n_active": len(self.active_members),
            "policy": self.policy,
            "disaggregated": self.disaggregate,
            "elastic": self.elastic,
            "routing": {"prefix": st.routed_prefix, "p2c": st.routed_p2c,
                        "random": st.routed_random, "spills": st.spills,
                        "prefix_route_rate": round(st.prefix_route_rate, 4)},
            "handoffs": st.handoffs,
            "recoveries": st.recoveries,
            "scale_events": list(st.scale_events),
            "members": [m.summary() for m in self.members],
            "batcher": self.batcher_stats.summary(),
        }


def run_fleet(server: LMServer, requests: Sequence[Request], *,
              concurrency: int = 32, return_stats: bool = False,
              **router_kwargs):
    """Closed-loop convenience driver: feed ``requests`` through a
    :class:`FleetRouter` with at most ``concurrency`` outstanding; returns
    completions in request order (plus the router summary when
    ``return_stats``).  This is what ``--fleet N`` runs in the serve
    launcher and benchmark."""
    async def go():
        sem = asyncio.Semaphore(max(1, concurrency))
        async with FleetRouter(server, **router_kwargs) as fleet:
            async def one(r: Request) -> Completion:
                async with sem:
                    return await fleet.submit(r)
            comps = list(await asyncio.gather(*[one(r) for r in requests]))
            return comps, fleet.summary()
    comps, summary = asyncio.run(go())
    return (comps, summary) if return_stats else comps
