"""Versioned wire protocol shared by every worker transport.

The paper's client ships a serialized payload over HTTP to a separately-
deployed entry point and reads back a serialized result (§4–§5).  This
module is that wire: one framed envelope format used identically by the
``processes`` transport (over a pipe) and the ``http`` transport (as POST
bodies), so transports differ only in how bytes move, never in what they
mean.

Frame layout::

    magic  b"RWIR" | version u16 | kind u8 | header_len u32
    header: JSON (utf-8) — routing + accounting metadata
    body:   raw bytes    — the function payload / result blob, untouched

Kinds:

* ``INVOKE``  — header {function, task_id, attempt, trace?, deadline?};
                body = payload blob.  ``trace`` (additive, absent unless
                the client sampled this request) is a span context dict —
                workers that predate it ignore the field.  ``deadline``
                (additive, ISSUE 10) is an absolute epoch-seconds cutoff:
                a worker receiving already-expired work rejects it with a
                non-retryable ``TimeoutError`` instead of computing it.
* ``RESULT``  — header {stats{deserialize_s,compute_s,serialize_s},
                server_s, cold_start, worker_id, spans?}; body = result
                blob.  ``spans`` (additive) carries the worker-side span
                dicts for a traced request back to the client collector.
* ``ERROR``   — header {etype, message, traceback, retryable, spans?};
                empty body.
                ``retryable=True`` marks infrastructure loss (the sandbox
                died) — the dispatcher's retry policy treats it as a
                ``WorkerCrash``; ``False`` marks a user-code error, which
                is surfaced (with the original remote traceback text)
                and never retried.
* ``CONTROL`` — header {op, ...}; worker-management verbs (ping, drain,
                state_lease / state_release / state_stats for worker-
                resident serving state, artifact_put for remote artifact
                fetch).  A CONTROL frame may carry a body (the artifact
                blob for ``artifact_put``); older verbs ignore it.

Malformed frames raise :class:`WireProtocolError` — a transport must turn
undecodable bytes into a visible invocation error, never a hung future.
"""
from __future__ import annotations

import builtins
import json
import struct
from dataclasses import dataclass, field
from typing import Any

MAGIC = b"RWIR"
WIRE_VERSION = 1

INVOKE, RESULT, ERROR, CONTROL = 1, 2, 3, 4
_HEADER = struct.Struct("<4sHBI")          # magic, version, kind, header_len


class WireProtocolError(RuntimeError):
    """The bytes on the wire are not a valid protocol frame."""


class RemoteTaskError(RuntimeError):
    """A user-code exception whose type could not be reconstructed locally.

    Carries ``remote_traceback`` — the original traceback text from the
    worker process.
    """


@dataclass
class InvokeRequest:
    function: str                  # mangled stable name (manifest key)
    payload: bytes
    task_id: int = 0
    attempt: int = 1
    trace: dict[str, Any] | None = None   # span context when client sampled
    deadline: float | None = None  # absolute epoch s; expired work rejected


@dataclass
class ResultReply:
    blob: bytes
    stats: dict[str, float] = field(default_factory=dict)
    server_s: float = 0.0
    cold_start: bool = False
    worker_id: int = -1
    spans: list[dict[str, Any]] = field(default_factory=list)


@dataclass
class ErrorReply:
    etype: str
    message: str
    traceback: str = ""
    retryable: bool = False
    spans: list[dict[str, Any]] = field(default_factory=list)


@dataclass
class ControlRequest:
    op: str                        # "ping" | "drain" | "state_*" | ...
    data: dict[str, Any] = field(default_factory=dict)
    body: bytes = b""              # op-specific blob (artifact_put)


# Error etype for a worker that cannot resolve an ArtifactRef locally; the
# client transports special-case it into a push-and-replay (remote fetch)
# instead of surfacing it.
ARTIFACT_MISSING = "ArtifactMissing"


def encode_artifact_missing(sha: str, path: str) -> bytes:
    return encode_error(etype=ARTIFACT_MISSING, retryable=False,
                        message=json.dumps({"sha": sha, "path": path}))


def decode_artifact_missing(reply: bytes) -> tuple[str, str] | None:
    """``(sha, path)`` if ``reply`` is an ArtifactMissing error, else None
    (including when the bytes are not a decodable frame at all — the
    ordinary completion path owns that diagnosis)."""
    try:
        msg = decode(reply)
    except WireProtocolError:
        return None
    if isinstance(msg, ErrorReply) and msg.etype == ARTIFACT_MISSING:
        try:
            d = json.loads(msg.message)
            return str(d["sha"]), str(d.get("path", ""))
        except (json.JSONDecodeError, KeyError, TypeError):
            return None
    return None


def _frame(kind: int, header: dict, body: bytes = b"") -> bytes:
    h = json.dumps(header, separators=(",", ":")).encode()
    return _HEADER.pack(MAGIC, WIRE_VERSION, kind, len(h)) + h + body


def encode_invoke(function: str, payload: bytes, *, task_id: int = 0,
                  attempt: int = 1,
                  trace: dict[str, Any] | None = None,
                  deadline: float | None = None) -> bytes:
    header: dict[str, Any] = {"function": function, "task_id": task_id,
                              "attempt": attempt}
    if trace:
        header["trace"] = trace
    if deadline is not None:
        header["deadline"] = round(float(deadline), 6)
    return _frame(INVOKE, header, payload)


def encode_result(blob: bytes, *, stats: dict[str, float] | None = None,
                  server_s: float = 0.0, cold_start: bool = False,
                  worker_id: int = -1,
                  spans: list[dict[str, Any]] | None = None) -> bytes:
    header: dict[str, Any] = {"stats": stats or {}, "server_s": server_s,
                              "cold_start": cold_start,
                              "worker_id": worker_id}
    if spans:
        header["spans"] = spans
    return _frame(RESULT, header, blob)


def encode_error(err: BaseException | None = None, *, etype: str | None = None,
                 message: str | None = None, traceback_text: str = "",
                 retryable: bool = False,
                 spans: list[dict[str, Any]] | None = None) -> bytes:
    if err is not None:
        etype = etype or type(err).__name__
        message = message if message is not None else str(err)
    header: dict[str, Any] = {"etype": etype or "RuntimeError",
                              "message": message or "",
                              "traceback": traceback_text,
                              "retryable": retryable}
    if spans:
        header["spans"] = spans
    return _frame(ERROR, header)


def encode_control(op: str, body: bytes = b"", **data: Any) -> bytes:
    return _frame(CONTROL, {"op": op, "data": data}, body)


def decode(data: bytes) -> InvokeRequest | ResultReply | ErrorReply | ControlRequest:
    """Parse one frame; raises :class:`WireProtocolError` on malformed input."""
    if len(data) < _HEADER.size:
        raise WireProtocolError(f"truncated frame ({len(data)} bytes)")
    magic, version, kind, hlen = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise WireProtocolError(f"bad magic {magic!r}")
    if version != WIRE_VERSION:
        raise WireProtocolError(f"wire version {version} unsupported "
                                f"(speaking {WIRE_VERSION})")
    off = _HEADER.size
    if len(data) < off + hlen:
        raise WireProtocolError("truncated header")
    try:
        header = json.loads(data[off:off + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireProtocolError(f"undecodable header: {e}") from None
    body = bytes(data[off + hlen:])
    try:
        if kind == INVOKE:
            return InvokeRequest(function=header["function"], payload=body,
                                 task_id=header.get("task_id", 0),
                                 attempt=header.get("attempt", 1),
                                 trace=header.get("trace"),
                                 deadline=header.get("deadline"))
        if kind == RESULT:
            return ResultReply(blob=body, stats=header.get("stats", {}),
                               server_s=header.get("server_s", 0.0),
                               cold_start=header.get("cold_start", False),
                               worker_id=header.get("worker_id", -1),
                               spans=header.get("spans", []))
        if kind == ERROR:
            return ErrorReply(etype=header.get("etype", "RuntimeError"),
                              message=header.get("message", ""),
                              traceback=header.get("traceback", ""),
                              retryable=header.get("retryable", False),
                              spans=header.get("spans", []))
        if kind == CONTROL:
            return ControlRequest(op=header["op"],
                                  data=header.get("data", {}), body=body)
    except KeyError as e:
        raise WireProtocolError(f"frame kind {kind} missing field {e}") from None
    raise WireProtocolError(f"unknown frame kind {kind}")


def to_exception(err: ErrorReply) -> BaseException:
    """Rebuild a local exception from an error envelope.

    Builtin exception types are reconstructed (so ``ValueError`` raised in a
    worker is still caught as ``ValueError`` by the client — backend choice
    must not change error-handling code); anything else becomes a
    :class:`RemoteTaskError`.  The original worker traceback text rides
    along as ``remote_traceback``.
    """
    cls = getattr(builtins, err.etype, None)
    if not (isinstance(cls, type) and issubclass(cls, BaseException)):
        cls = RemoteTaskError
        exc: BaseException = cls(f"{err.etype}: {err.message}")
    else:
        try:
            exc = cls(err.message)
        except Exception:
            exc = RemoteTaskError(f"{err.etype}: {err.message}")
    exc.remote_traceback = err.traceback       # type: ignore[attr-defined]
    return exc
