"""Content-addressed payload constants — params ship once, not per batch.

Serving payloads repeat one large constant in every request: the model
params.  Measured on the serve bench, the per-batch payload is ~98%
params bytes, and client serialize + worker deserialize of those bytes
dominates the roundtrip — the scheduler can't matter while every batch
re-ships the model.

:class:`ArtifactRef` is the fix, shaped like the paper's deployment flow
(the artifact is *uploaded once* by the deployment tool; invocations
reference it): ``put_artifact(value)`` serializes a value into a
content-addressed file (``sha256(blob).bin``) and returns a tiny
``(path, sha)`` pointer that takes the value's place inside any payload
tree.  Deserialization resolves the pointer through a process-level cache,
so the bytes cross the wire and the deserializer **once per worker
process**, then every later payload pays ~nothing.

The store is a shared-filesystem directory — the same trust/availability
contract as the deployment manifest file (which the out-of-process
transports already share by path), and the local analogue of an S3
bucket.  An external worker on another machine needs the directory
mounted, exactly as it needs the manifest.

Integrity: the sha is verified on load, so a truncated or overwritten
artifact file fails loudly instead of silently serving a corrupt model.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import threading
from dataclasses import dataclass
from typing import Any

from .pytree import register_custom


@dataclass(frozen=True)
class ArtifactRef:
    """Pointer to a content-addressed artifact: travels in payloads in
    place of the value it names."""
    path: str
    sha: str


_CACHE: dict[str, Any] = {}
_CACHE_LOCK = threading.Lock()


def default_artifact_dir() -> str:
    return os.environ.get(
        "REPRO_ARTIFACT_DIR",
        os.path.join(tempfile.gettempdir(), "repro-artifacts"))


def put_artifact(value: Any, directory: str | None = None) -> ArtifactRef:
    """Serialize ``value`` into the store (idempotent: content-addressed)
    and return the reference that stands in for it in payloads."""
    from .archive import serialize
    blob = serialize(value)
    sha = hashlib.sha256(blob).hexdigest()
    d = directory or default_artifact_dir()
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{sha}.bin")
    if not os.path.exists(path):
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)          # atomic; concurrent writers converge
    with _CACHE_LOCK:
        # the producer keeps the live value: local backends resolve with
        # zero IO and zero extra copies
        _CACHE.setdefault(sha, value)
    return ArtifactRef(path=path, sha=sha)


def load_artifact(ref: ArtifactRef) -> Any:
    """Resolve a reference: process-level cache, then the store file
    (sha-verified)."""
    with _CACHE_LOCK:
        if ref.sha in _CACHE:
            return _CACHE[ref.sha]
    from .archive import deserialize
    with open(ref.path, "rb") as f:
        blob = f.read()
    sha = hashlib.sha256(blob).hexdigest()
    if sha != ref.sha:
        raise ValueError(
            f"artifact {ref.path} content hash {sha[:12]}… does not match "
            f"reference {ref.sha[:12]}… (corrupt or overwritten store file)")
    value = deserialize(blob)
    with _CACHE_LOCK:
        _CACHE.setdefault(ref.sha, value)
    return _CACHE[ref.sha]


def resolve_artifacts(tree: Any) -> Any:
    """Deep-map a payload tree, replacing every ``ArtifactRef`` with its
    value.  Deserialization does this implicitly (the registered wire type
    loads on decode); this explicit form is for code paths that receive
    the *original* python objects — fingerprinting and AOT specialization,
    which must see real arrays, not pointers."""
    if isinstance(tree, ArtifactRef):
        return load_artifact(tree)
    if isinstance(tree, dict):
        return {k: resolve_artifacts(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(resolve_artifacts(v) for v in tree)
    return tree


# Wire registration: an ArtifactRef serializes as its two strings and
# *resolves on deserialize* — the receiving side transparently sees the
# value.  Registered at import; both client and worker import this module
# through ``repro.serialization``.
register_custom(
    ArtifactRef,
    to_tree=lambda r: {"path": r.path, "sha": r.sha},
    from_tree=lambda d: load_artifact(ArtifactRef(**d)),
)
