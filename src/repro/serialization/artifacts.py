"""Content-addressed payload constants — params ship once, not per batch.

Serving payloads repeat one large constant in every request: the model
params.  Measured on the serve bench, the per-batch payload is ~98%
params bytes, and client serialize + worker deserialize of those bytes
dominates the roundtrip — the scheduler can't matter while every batch
re-ships the model.

:class:`ArtifactRef` is the fix, shaped like the paper's deployment flow
(the artifact is *uploaded once* by the deployment tool; invocations
reference it): ``put_artifact(value)`` serializes a value into a
content-addressed file (``sha256(blob).bin``) and returns a tiny
``(path, sha)`` pointer that takes the value's place inside any payload
tree.  Deserialization resolves the pointer through a process-level cache,
so the bytes cross the wire and the deserializer **once per worker
process**, then every later payload pays ~nothing.

The store is a shared-filesystem directory — the same trust/availability
contract as the deployment manifest file (which the out-of-process
transports already share by path), and the local analogue of an S3
bucket.  An external worker on another machine needs the directory
mounted, exactly as it needs the manifest.

Integrity: the sha is verified on load, so a truncated or overwritten
artifact file fails loudly instead of silently serving a corrupt model.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import threading
from dataclasses import dataclass
from typing import Any

from .pytree import register_custom


@dataclass(frozen=True)
class ArtifactRef:
    """Pointer to a content-addressed artifact: travels in payloads in
    place of the value it names."""
    path: str
    sha: str


class ArtifactMissingError(RuntimeError):
    """A reference names a blob this process cannot find anywhere local.

    On a worker this is the remote-fetch trigger: the worker host answers
    with an ``ArtifactMissing`` wire error, the client transport pushes
    the blob over a CONTROL frame and replays the invocation — so
    ``url=``-external workers no longer require a shared filesystem.
    """

    def __init__(self, ref: "ArtifactRef"):
        super().__init__(
            f"artifact {ref.sha[:12]}… not found (looked in the process "
            f"cache, {ref.path!r}, and the local store)")
        self.sha = ref.sha
        self.path = ref.path


_CACHE: dict[str, Any] = {}
_CACHE_LOCK = threading.Lock()
# refs produced by THIS process that are still live (put minus release),
# counted per sha: content-addressing means two producers of identical
# params share one blob, and pruning must outlive the first one to close
_LIVE: dict[str, int] = {}
# every sha this process ever put: the default GC sweep only reaps its own
# garbage, so concurrent serve processes sharing the store directory can't
# delete each other's live params out from under a cold worker
_PRODUCED: set[str] = set()


def default_artifact_dir() -> str:
    return os.environ.get(
        "REPRO_ARTIFACT_DIR",
        os.path.join(tempfile.gettempdir(), "repro-artifacts"))


def put_artifact(value: Any, directory: str | None = None) -> ArtifactRef:
    """Serialize ``value`` into the store (idempotent: content-addressed)
    and return the reference that stands in for it in payloads."""
    from .archive import serialize
    blob = serialize(value)
    sha = hashlib.sha256(blob).hexdigest()
    d = directory or default_artifact_dir()
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{sha}.bin")
    if not os.path.exists(path):
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)          # atomic; concurrent writers converge
    with _CACHE_LOCK:
        # the producer keeps the live value: local backends resolve with
        # zero IO and zero extra copies
        _CACHE.setdefault(sha, value)
        _LIVE[sha] = _LIVE.get(sha, 0) + 1
        _PRODUCED.add(sha)
    return ArtifactRef(path=path, sha=sha)


def release_artifact(ref: ArtifactRef) -> None:
    """Drop one live claim on ``ref`` (the producer is done with it).  The
    blob itself is only removed by :func:`prune_artifacts`; releasing just
    makes it eligible.  Also evicts the process cache entry once the last
    claim drops, so a served model's params don't outlive their server."""
    with _CACHE_LOCK:
        n = _LIVE.get(ref.sha, 0) - 1
        if n > 0:
            _LIVE[ref.sha] = n
        else:
            _LIVE.pop(ref.sha, None)
            _CACHE.pop(ref.sha, None)


def prune_artifacts(keep: Any = (), directory: str | None = None,
                    all_blobs: bool = False) -> list[str]:
    """Garbage-collect the store: unlink blobs not named by ``keep`` and
    not live in this process (``put_artifact`` without a matching
    :func:`release_artifact`).  Returns the removed paths.

    The content-addressed store grows without bound otherwise — every
    distinct params tree ever served leaves a blob behind.  Callers pass
    the refs they still need (``keep=[ref, ...]``); :meth:`LMServer.close`
    does this on teardown.  By default only blobs THIS process produced
    are candidates, so concurrent serve processes sharing the store
    directory never reap each other's live params; ``all_blobs=True``
    sweeps everything in the directory (use it from a coordinating client
    to clear garbage left by dead processes).
    """
    keep_shas = {r.sha for r in keep}
    d = directory or default_artifact_dir()
    removed: list[str] = []
    if not os.path.isdir(d):
        return removed
    for name in os.listdir(d):
        if not name.endswith(".bin"):
            continue
        sha = name[:-len(".bin")]
        if sha in keep_shas:
            continue
        path = os.path.join(d, name)
        with _CACHE_LOCK:
            # liveness re-checked under the lock at unlink time: a blob
            # put by a concurrent thread after a snapshot would otherwise
            # be deleted while live
            if sha in _LIVE or (not all_blobs and sha not in _PRODUCED):
                continue
            try:
                os.unlink(path)
            except OSError:
                continue                # raced another pruner / still open
            _CACHE.pop(sha, None)
            _PRODUCED.discard(sha)
        removed.append(path)
    return removed


def load_artifact(ref: ArtifactRef) -> Any:
    """Resolve a reference: process-level cache, then the referenced store
    file, then the *local* store directory (where a remote fetch deposits
    blobs when the referenced path was another machine's).  All file loads
    are sha-verified.  A blob found nowhere raises
    :class:`ArtifactMissingError` — the remote-fetch trigger."""
    with _CACHE_LOCK:
        if ref.sha in _CACHE:
            return _CACHE[ref.sha]
    from .archive import deserialize
    blob = None
    local = os.path.join(default_artifact_dir(), f"{ref.sha}.bin")
    for path in (ref.path, local):
        try:
            with open(path, "rb") as f:
                blob = f.read()
            break
        except OSError:
            continue
    if blob is None:
        raise ArtifactMissingError(ref)
    sha = hashlib.sha256(blob).hexdigest()
    if sha != ref.sha:
        raise ValueError(
            f"artifact {ref.path} content hash {sha[:12]}… does not match "
            f"reference {ref.sha[:12]}… (corrupt or overwritten store file)")
    value = deserialize(blob)
    with _CACHE_LOCK:
        _CACHE.setdefault(ref.sha, value)
    return _CACHE[ref.sha]


def export_artifact_blob(sha: str, path: str = "") -> bytes | None:
    """Client side of remote fetch: the raw store bytes for ``sha`` — from
    the referenced file, the local store, or (for a pruned file whose
    value is still live here) by re-serializing the cached value.  None if
    this process has no way to produce them."""
    from .archive import serialize
    local = os.path.join(default_artifact_dir(), f"{sha}.bin")
    for p in (path, local):
        if not p:
            continue
        try:
            with open(p, "rb") as f:
                blob = f.read()
        except OSError:
            continue
        if hashlib.sha256(blob).hexdigest() == sha:
            return blob
    with _CACHE_LOCK:
        value = _CACHE.get(sha)
    if value is None:
        return None
    blob = serialize(value)
    return blob if hashlib.sha256(blob).hexdigest() == sha else None


def import_artifact_blob(sha: str, blob: bytes,
                         directory: str | None = None) -> str:
    """Worker side of remote fetch: verify and deposit pushed bytes into
    the local store, where :func:`load_artifact` finds them on replay."""
    got = hashlib.sha256(blob).hexdigest()
    if got != sha:
        raise ValueError(f"pushed artifact hash {got[:12]}… does not match "
                         f"announced {sha[:12]}…")
    d = directory or default_artifact_dir()
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{sha}.bin")
    if not os.path.exists(path):
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
    return path


def resolve_artifacts(tree: Any) -> Any:
    """Deep-map a payload tree, replacing every ``ArtifactRef`` with its
    value.  Deserialization does this implicitly (the registered wire type
    loads on decode); this explicit form is for code paths that receive
    the *original* python objects — fingerprinting and AOT specialization,
    which must see real arrays, not pointers."""
    if isinstance(tree, ArtifactRef):
        return load_artifact(tree)
    if isinstance(tree, dict):
        return {k: resolve_artifacts(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(resolve_artifacts(v) for v in tree)
    return tree


# Wire registration: an ArtifactRef serializes as its two strings and
# *resolves on deserialize* — the receiving side transparently sees the
# value.  Registered at import; both client and worker import this module
# through ``repro.serialization``.
register_custom(
    ArtifactRef,
    to_tree=lambda r: {"path": r.path, "sha": r.sha},
    from_tree=lambda d: load_artifact(ArtifactRef(**d)),
)
