"""Pytree reflection: the JAX analogue of Cppless's lambda-capture reflection.

Cppless adds a compiler extension exposing constexpr accessors to the unnamed
capture members of a C++ lambda so that generic serialization can visit every
captured value (paper §4.3).  In JAX the captured state of a task is a pytree,
and ``jax.tree_util`` already provides the generic, typed traversal — this
module pins down a *stable, wire-format-friendly* spec for that traversal so a
tree can be rebuilt on the remote side without Python pickling.

The spec is a JSON-able recursive description::

    {"t": "dict",   "keys": [...], "children": [spec, ...]}
    {"t": "list",   "children": [...]}
    {"t": "tuple",  "children": [...]}
    {"t": "none"}
    {"t": "leaf"}                      # consumes the next leaf in order
    {"t": "custom", "name": <registered>, "child": spec}

Custom types mirror cereal's user-supplied ``serialize`` methods: users
register a (to_tree, from_tree) pair per class (paper §3.3: "the user only has
to manually add serialization for custom types").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

# Leaf types the wire format understands natively.
LEAF_TYPES = (np.ndarray, np.generic, int, float, bool, str, bytes)

_CUSTOM_BY_CLS: dict[type, tuple[str, Callable, Callable]] = {}
_CUSTOM_BY_NAME: dict[str, tuple[type, Callable, Callable]] = {}


def register_custom(
    cls: type,
    name: str | None = None,
    to_tree: Callable[[Any], Any] | None = None,
    from_tree: Callable[[Any], Any] | None = None,
) -> None:
    """Register serialization for a custom type (cereal-style).

    Defaults handle ``@dataclasses.dataclass`` classes automatically.
    """
    name = name or f"{cls.__module__}.{cls.__qualname__}"
    if to_tree is None or from_tree is None:
        if not dataclasses.is_dataclass(cls):
            raise TypeError(
                f"{cls!r} is not a dataclass; provide to_tree/from_tree "
                "(the cereal analogue of a custom serialize method)"
            )
        fields = [f.name for f in dataclasses.fields(cls)]
        to_tree = lambda obj, _f=fields: {k: getattr(obj, k) for k in _f}  # noqa: E731
        from_tree = lambda tree, _c=cls: _c(**tree)  # noqa: E731
    _CUSTOM_BY_CLS[cls] = (name, to_tree, from_tree)
    _CUSTOM_BY_NAME[name] = (cls, to_tree, from_tree)


def _is_jax_array(x: Any) -> bool:
    # Avoid importing jax at module scope cost; duck-type on __array__ + dtype.
    mod = type(x).__module__
    return mod.startswith("jax") and hasattr(x, "dtype")


def flatten(tree: Any) -> tuple[dict, list]:
    """Flatten ``tree`` into (spec, leaves).  JAX arrays become numpy."""
    leaves: list = []

    def rec(node: Any) -> dict:
        if node is None:
            return {"t": "none"}
        if _is_jax_array(node):
            node = np.asarray(node)
        if isinstance(node, LEAF_TYPES):
            leaves.append(node)
            return {"t": "leaf"}
        if type(node) in _CUSTOM_BY_CLS:
            name, to_tree, _ = _CUSTOM_BY_CLS[type(node)]
            return {"t": "custom", "name": name, "child": rec(to_tree(node))}
        if isinstance(node, dict):
            keys = list(node.keys())
            if not all(isinstance(k, str) for k in keys):
                raise TypeError("only str dict keys are wire-serializable")
            return {"t": "dict", "keys": keys,
                    "children": [rec(node[k]) for k in keys]}
        if isinstance(node, tuple):
            return {"t": "tuple", "children": [rec(c) for c in node]}
        if isinstance(node, list):
            return {"t": "list", "children": [rec(c) for c in node]}
        raise TypeError(
            f"cannot serialize {type(node)!r}; register_custom() it first"
        )

    spec = rec(tree)
    return spec, leaves


def unflatten(spec: dict, leaves: list) -> Any:
    """Rebuild a tree from (spec, leaves)."""
    it = iter(leaves)

    def rec(s: dict) -> Any:
        t = s["t"]
        if t == "none":
            return None
        if t == "leaf":
            return next(it)
        if t == "dict":
            return {k: rec(c) for k, c in zip(s["keys"], s["children"])}
        if t == "tuple":
            return tuple(rec(c) for c in s["children"])
        if t == "list":
            return [rec(c) for c in s["children"]]
        if t == "custom":
            name = s["name"]
            if name not in _CUSTOM_BY_NAME:
                raise KeyError(f"custom type {name!r} not registered on this side")
            _, _, from_tree = _CUSTOM_BY_NAME[name]
            return from_tree(rec(s["child"]))
        raise ValueError(f"bad spec node {s!r}")

    out = rec(spec)
    rest = list(it)
    if rest:
        raise ValueError(f"{len(rest)} unconsumed leaves")
    return out
