from .archive import (FORMATS, decode_binary, decode_binary_json,
                      decode_structured_json, deserialize, encode_binary,
                      encode_binary_json, encode_structured_json, serialize)
from .artifacts import (ArtifactMissingError, ArtifactRef,
                        export_artifact_blob, import_artifact_blob,
                        load_artifact, prune_artifacts, put_artifact,
                        release_artifact, resolve_artifacts)
from .pytree import flatten, register_custom, unflatten
from . import wire

__all__ = [
    "FORMATS", "serialize", "deserialize", "encode_binary", "decode_binary",
    "encode_binary_json", "decode_binary_json", "encode_structured_json",
    "decode_structured_json", "flatten", "unflatten", "register_custom",
    "wire", "ArtifactRef", "ArtifactMissingError", "put_artifact",
    "load_artifact", "resolve_artifacts", "prune_artifacts",
    "release_artifact", "export_artifact_blob", "import_artifact_blob",
]
