"""Typed archives: ``binary`` / ``binary_json`` / ``structured_json``.

Reproduces Cppless's serialization stack (paper §5.1, Tables 9/10), which uses
cereal archives to beat the loosely-typed-JSON wall of FaaS REST APIs:

* ``binary``          — raw little-endian typed encoding (cereal binary).
* ``binary_json``     — the binary blob base64-wrapped in a JSON envelope;
                        what a JSON-only cloud API forces you to ship.
* ``structured_json`` — fully structured "vanilla" JSON (numbers as text);
                        the slow baseline the paper measures against.

The binary format doubles as the checkpoint wire format (``compress=True``
adds a zstd frame), turning the paper's microbench artifact into first-class
training infrastructure.

Wire layout (binary)::

    magic   b"RPRO"  | version u16 | flags u16 (bit0 = zstd over body)
    body:
      spec_len u64 | spec_json utf-8
      nleaves  u64
      per leaf: tag u8
        tag 0 ndarray: dlen u16 | dtype-str | ndim u8 | shape i64*ndim | raw C-order bytes
        tag 1 int:    i64        tag 2 float: f64       tag 3 bool: u8
        tag 4 str:    u64 len | utf-8
        tag 5 bytes:  u64 len | raw
"""
from __future__ import annotations

import base64
import json
import struct
from typing import Any

import numpy as np

from . import pytree

try:  # optional, used for checkpoint compression frames
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None

try:  # registers bfloat16/fp8 dtype names with numpy
    import ml_dtypes  # noqa: F401
except Exception:  # pragma: no cover
    pass

MAGIC = b"RPRO"
VERSION = 1
_FLAG_ZSTD = 1

FORMATS = ("binary", "binary_json", "structured_json")


# ---------------------------------------------------------------- binary ----

def _encode_leaf(leaf: Any, out: list) -> None:
    if isinstance(leaf, np.generic):
        leaf = np.asarray(leaf)
    if isinstance(leaf, np.ndarray):
        if leaf.dtype.hasobject:
            raise TypeError("object arrays are not wire-serializable")
        arr = leaf  # .tobytes() below always emits C-order, 0-d safe
        # Extension dtypes (bfloat16, fp8) stringify as '<V2'; use the name.
        dt_s = arr.dtype.str if arr.dtype.kind != "V" else str(arr.dtype)
        dt = dt_s.encode()  # e.g. b'<f4' or b'bfloat16'
        out.append(struct.pack("<BH", 0, len(dt)))
        out.append(dt)
        out.append(struct.pack("<B", arr.ndim))
        out.append(struct.pack(f"<{arr.ndim}q", *arr.shape))
        out.append(arr.tobytes())
    elif isinstance(leaf, bool):  # before int: bool is an int subclass
        out.append(struct.pack("<BB", 3, int(leaf)))
    elif isinstance(leaf, int):
        out.append(struct.pack("<Bq", 1, leaf))
    elif isinstance(leaf, float):
        out.append(struct.pack("<Bd", 2, leaf))
    elif isinstance(leaf, str):
        b = leaf.encode()
        out.append(struct.pack("<BQ", 4, len(b)))
        out.append(b)
    elif isinstance(leaf, bytes):
        out.append(struct.pack("<BQ", 5, len(leaf)))
        out.append(leaf)
    else:  # pragma: no cover
        raise TypeError(f"unhandled leaf {type(leaf)!r}")


def _decode_leaf(buf: memoryview, off: int) -> tuple[Any, int]:
    (tag,) = struct.unpack_from("<B", buf, off)
    off += 1
    if tag == 0:
        (dlen,) = struct.unpack_from("<H", buf, off)
        off += 2
        dt = np.dtype(bytes(buf[off : off + dlen]).decode())
        off += dlen
        (ndim,) = struct.unpack_from("<B", buf, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", buf, off)
        off += 8 * ndim
        n = int(np.prod(shape)) if ndim else 1
        nbytes = n * dt.itemsize
        # zero-copy: a read-only view into the (immutable bytes) buffer —
        # decode throughput doubles; consumers copy iff they mutate.
        arr = np.frombuffer(buf, dtype=dt, count=n, offset=off).reshape(shape)
        return arr, off + nbytes
    if tag == 1:
        (v,) = struct.unpack_from("<q", buf, off)
        return v, off + 8
    if tag == 2:
        (v,) = struct.unpack_from("<d", buf, off)
        return v, off + 8
    if tag == 3:
        (v,) = struct.unpack_from("<B", buf, off)
        return bool(v), off + 1
    if tag == 4:
        (n,) = struct.unpack_from("<Q", buf, off)
        off += 8
        return bytes(buf[off : off + n]).decode(), off + n
    if tag == 5:
        (n,) = struct.unpack_from("<Q", buf, off)
        off += 8
        return bytes(buf[off : off + n]), off + n
    raise ValueError(f"bad leaf tag {tag}")


def _binary_parts(tree: Any) -> list:
    """Body as a chunk list — joined exactly once by the caller (a second
    header+body concat would re-copy multi-MB payloads)."""
    spec, leaves = pytree.flatten(tree)
    spec_b = json.dumps(spec, separators=(",", ":")).encode()
    out: list = [struct.pack("<Q", len(spec_b)), spec_b,
                 struct.pack("<Q", len(leaves))]
    for leaf in leaves:
        _encode_leaf(leaf, out)
    return out


def _binary_parse(body: bytes) -> Any:
    buf = memoryview(body)
    (spec_len,) = struct.unpack_from("<Q", buf, 0)
    off = 8
    spec = json.loads(bytes(buf[off : off + spec_len]).decode())
    off += spec_len
    (nleaves,) = struct.unpack_from("<Q", buf, off)
    off += 8
    leaves = []
    for _ in range(nleaves):
        leaf, off = _decode_leaf(buf, off)
        leaves.append(leaf)
    return pytree.unflatten(spec, leaves)


def encode_binary(tree: Any, compress: bool = False, level: int = 3) -> bytes:
    parts = _binary_parts(tree)
    flags = 0
    if compress:
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard unavailable")
        body = _zstd.ZstdCompressor(level=level).compress(b"".join(parts))
        flags |= _FLAG_ZSTD
        return MAGIC + struct.pack("<HH", VERSION, flags) + body
    return b"".join([MAGIC, struct.pack("<HH", VERSION, flags), *parts])


def decode_binary(data: bytes) -> Any:
    if data[:4] != MAGIC:
        raise ValueError("not an RPRO binary archive")
    version, flags = struct.unpack_from("<HH", data, 4)
    if version != VERSION:
        raise ValueError(f"archive version {version} unsupported")
    body = data[8:]
    if flags & _FLAG_ZSTD:
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard unavailable")
        body = _zstd.ZstdDecompressor().decompress(body)
    return _binary_parse(body)


# ----------------------------------------------------------- binary_json ----

def encode_binary_json(tree: Any) -> bytes:
    blob = encode_binary(tree)
    return json.dumps(
        {"format": "binary_json", "payload": base64.b64encode(blob).decode()}
    ).encode()


def decode_binary_json(data: bytes) -> Any:
    return _decode_binary_json_doc(json.loads(data.decode()))


def _decode_binary_json_doc(doc: dict) -> Any:
    return decode_binary(base64.b64decode(doc["payload"]))


# ------------------------------------------------------- structured_json ----

def _leaf_to_json(leaf: Any) -> Any:
    if isinstance(leaf, np.generic):
        leaf = np.asarray(leaf)
    if isinstance(leaf, np.ndarray):
        # bf16 & friends have no JSON-number representation; go through float.
        data = leaf
        if data.dtype.kind == "V" or data.dtype.str in ("<V2", "bfloat16"):
            data = data.astype(np.float32)
        if str(leaf.dtype) == "bfloat16":
            data = leaf.astype(np.float32)
        return {"__nd__": True, "dtype": str(leaf.dtype),
                "shape": list(leaf.shape), "data": data.tolist()}
    if isinstance(leaf, bytes):
        return {"__bytes__": base64.b64encode(leaf).decode()}
    return leaf


def _leaf_from_json(obj: Any) -> Any:
    if isinstance(obj, dict) and obj.get("__nd__"):
        import ml_dtypes  # noqa: F401  (registers bfloat16 with numpy if present)

        arr = np.array(obj["data"], dtype=np.dtype(obj["dtype"]))
        return arr.reshape(obj["shape"])
    if isinstance(obj, dict) and "__bytes__" in obj:
        return base64.b64decode(obj["__bytes__"])
    return obj


def encode_structured_json(tree: Any) -> bytes:
    spec, leaves = pytree.flatten(tree)
    doc = {"format": "structured_json", "spec": spec,
           "leaves": [_leaf_to_json(leaf) for leaf in leaves]}
    return json.dumps(doc).encode()


def decode_structured_json(data: bytes) -> Any:
    return _decode_structured_json_doc(json.loads(data.decode()))


def _decode_structured_json_doc(doc: dict) -> Any:
    leaves = [_leaf_from_json(o) for o in doc["leaves"]]
    return pytree.unflatten(doc["spec"], leaves)


# ----------------------------------------------------------------- facade ---

def serialize(tree: Any, format: str = "binary", **kw) -> bytes:
    if format == "binary":
        return encode_binary(tree, **kw)
    if format == "binary_json":
        return encode_binary_json(tree)
    if format == "structured_json":
        return encode_structured_json(tree)
    raise ValueError(f"unknown format {format!r}; choose from {FORMATS}")


def deserialize(data: bytes, format: str | None = None) -> Any:
    if format is None:  # sniff
        if data[:4] == MAGIC:
            return decode_binary(data)
        # JSON envelope: dispatch on the parsed "format" field, not on a
        # byte-prefix match — key order, whitespace, and indentation are
        # producer choices the wire format must not depend on.
        doc = json.loads(data.decode())
        fmt = doc.get("format", "structured_json") if isinstance(doc, dict) \
            else "structured_json"
        if fmt == "binary_json":
            return _decode_binary_json_doc(doc)
        if fmt == "structured_json":
            return _decode_structured_json_doc(doc)
        raise ValueError(f"unknown archive format field {fmt!r}")
    if format == "binary":
        return decode_binary(data)
    if format == "binary_json":
        return decode_binary_json(data)
    if format == "structured_json":
        return decode_structured_json(data)
    raise ValueError(f"unknown format {format!r}")
