"""Offline linter: ``python -m repro.analysis <module-or-path> ...``.

Discovery is AST-based and the linted file is **never executed**: source
is parsed to find remote call sites — ``@session.remote``-style decorators
and ``session.function(...)`` / ``.remote(...)`` / ``.deploy(...)`` calls
(including inline lambdas) — then ``compile()``d, and the code objects
matching the discovered sites are fed to :func:`analyze_code`.

Because no values exist at lint time, the capture-probe rules
(RF102/RF103/RF104) cannot fire here; the bytecode rules do.  The module
name is derived by walking up the ``__init__.py`` chain, so functions in
importable packages are not RF101-flagged while bare scripts (the
``__main__`` fresh-globals contract) are.

Exit status: 1 if any ``error``-severity diagnostic (any diagnostic at
all under ``--strict``), else 0 — the CI self-lint contract.
"""
from __future__ import annotations

import argparse
import ast
import importlib.util
import json
import sys
import types
from pathlib import Path
from typing import Iterator

from .analyzer import analyze_code
from .diagnostics import Diagnostic

__all__ = ["main", "lint_file", "discover_targets"]


# ------------------------------------------------------------- discovery

_REMOTE_ATTRS = frozenset({"remote", "function", "deploy"})


def _decorator_is_remote(dec: ast.expr) -> bool:
    if isinstance(dec, ast.Call):
        dec = dec.func
    if isinstance(dec, ast.Attribute):
        return dec.attr == "remote"
    if isinstance(dec, ast.Name):
        return dec.id == "remote"
    return False


def discover_targets(tree: ast.Module) -> list[tuple[str, int]]:
    """(name, lineno) pairs for every remote-function site in a module.

    * ``def f`` decorated with ``@<anything>.remote`` / ``@remote(...)``
    * ``<anything>.function(f, ...)`` / ``.remote(f)`` / ``.deploy(f)``
      where ``f`` is a module-level def or an inline lambda
    """
    defs: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)

    targets: dict[tuple[str, int], None] = {}

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_decorator_is_remote(d) for d in node.decorator_list):
                targets[(node.name, node.lineno)] = None
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _REMOTE_ATTRS:
            for arg in node.args:
                if isinstance(arg, ast.Lambda):
                    targets[("<lambda>", arg.lineno)] = None
                elif isinstance(arg, ast.Name) and arg.id in defs:
                    d = defs[arg.id]
                    targets[(d.name, d.lineno)] = None
    return list(targets)


def _iter_codes(code: types.CodeType) -> Iterator[types.CodeType]:
    yield code
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            yield from _iter_codes(const)


def _module_name_for(path: Path) -> str | None:
    """Dotted module name if ``path`` sits inside a package, else None.

    ``None`` means the file is a bare script: its functions live under
    ``__main__`` when run, which arms the RF101 fresh-globals rule — the
    same judgement ``freeze_function`` makes at runtime.
    """
    path = path.resolve()
    parts = [] if path.name == "__init__.py" else [path.stem]
    cur = path.parent
    # regular packages: walk the __init__.py chain
    while (cur / "__init__.py").exists():
        parts.insert(0, cur.name)
        cur = cur.parent
    # namespace packages have no __init__.py, so keep prepending parent
    # dirs; accept a candidate only if it resolves to exactly this file
    # (guards against shadowing an unrelated installed module)
    for _ in range(4):
        if parts and len(parts) > (0 if path.name == "__init__.py" else 1):
            name = ".".join(parts)
            try:
                spec = importlib.util.find_spec(name)
            except (ImportError, ValueError):
                spec = None
            if spec is not None and spec.origin and \
                    Path(spec.origin).resolve() == path:
                return name
        if cur == cur.parent:
            break
        parts.insert(0, cur.name)
        cur = cur.parent
    return None


def lint_file(path: Path) -> tuple[int, list[Diagnostic]]:
    """Lint one source file; returns (#target functions, diagnostics)."""
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    sites = discover_targets(tree)
    if not sites:
        return 0, []
    code = compile(source, str(path), "exec", dont_inherit=True)
    module = _module_name_for(path)
    wanted = {(n, l) for n, l in sites}
    out: list[Diagnostic] = []
    hit = 0
    for c in _iter_codes(code):
        if (c.co_name, c.co_firstlineno) in wanted:
            hit += 1
            out.extend(analyze_code(c, module=module, qualname=c.co_name))
    return hit, out


def _resolve(spec: str) -> list[Path]:
    p = Path(spec)
    if p.is_dir():
        return sorted(q for q in p.rglob("*.py") if q.is_file())
    if p.is_file():
        return [p]
    # dotted module name
    try:
        found = importlib.util.find_spec(spec)
    except (ImportError, ValueError):
        found = None
    if found is not None and found.origin and found.origin.endswith(".py"):
        origin = Path(found.origin)
        if found.submodule_search_locations:      # package: lint the tree
            return sorted(q for q in origin.parent.rglob("*.py")
                          if q.is_file())
        return [origin]
    raise FileNotFoundError(f"no such file, directory or module: {spec!r}")


# ------------------------------------------------------------------ main

def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Shippability linter for repro remote functions.")
    ap.add_argument("targets", nargs="+",
                    help="source file, directory, or dotted module name")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any diagnostic, not just errors")
    args = ap.parse_args(argv)

    files: list[Path] = []
    for spec in args.targets:
        try:
            files.extend(_resolve(spec))
        except FileNotFoundError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    n_funcs = 0
    diags: list[Diagnostic] = []
    n_files = 0
    for f in files:
        try:
            hit, out = lint_file(f)
        except SyntaxError as e:
            print(f"error: {f}: {e}", file=sys.stderr)
            return 2
        n_files += 1
        n_funcs += hit
        diags.extend(out)

    errors = sum(d.severity == "error" for d in diags)
    warnings = sum(d.severity == "warning" for d in diags)

    if args.format == "json":
        print(json.dumps({
            "version": 1,
            "files": n_files,
            "functions": n_funcs,
            "errors": errors,
            "warnings": warnings,
            "diagnostics": [d.to_json() for d in diags],
        }, indent=2))
    else:
        for d in diags:
            print(d.format())
        print(f"[repro.analysis] {n_files} file(s), {n_funcs} remote "
              f"function(s): {errors} error(s), {warnings} warning(s), "
              f"{len(diags) - errors - warnings} info")

    if errors or (args.strict and diags):
        return 1
    return 0
