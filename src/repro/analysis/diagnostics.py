"""Diagnostic objects, the rule table, and the strictness contract.

A :class:`Diagnostic` is one compiler-style finding: stable rule code,
severity, offending symbol, and a ``file:line`` source location taken from
``co_filename``/``co_firstlineno`` plus the instruction line the pattern
matched on.  Severities gate behavior:

* ``error``   — the function **will** fail (or silently lose data) when
  shipped; ``Session(strict_analysis=True)`` / ``FunctionConfig.strict``
  turn these into :class:`AnalysisError` at deploy time.
* ``warning`` — the function ships but its semantics diverge from the
  local call (lost writes, broken bit-identity); surfaced via
  :class:`ShippabilityWarning` on deploy and failed by the CLI only under
  ``--strict``.
* ``info``    — worth knowing (a capture ships by value, not as code);
  shown by the CLI, silent at deploy time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

SEVERITIES = ("error", "warning", "info")

# Stable rule registry: code -> (default severity, one-line title).  The
# rule-code table in API.md mirrors this dict; tests assert membership so
# codes never silently disappear.
RULES: dict[str, tuple[str, str]] = {
    "RF101": ("error",
              "global name unresolvable on the worker (fresh-globals "
              "contract for __main__/script functions)"),
    "RF102": ("error",
              "capture is a host-only resource (lock/file/socket/session) "
              "that cannot cross a process boundary"),
    "RF103": ("error",
              "capture failed the wire-serialization probe"),
    "RF104": ("info",
              "callable capture without __code__ and without an importable "
              "ref ships by value in the payload, not as code"),
    "RF201": ("warning",
              "write to a captured variable — by-value shipping makes it a "
              "lost write"),
    "RF202": ("warning",
              "write to a global — worker-side module state never reaches "
              "the client"),
    "RF203": ("warning",
              "mutating call/assignment on a captured object — the worker "
              "mutates a copy"),
    "RF301": ("warning",
              "nondeterministic call (random/uuid/secrets/os.urandom/"
              "wall-clock) breaks the bit-identity invariance contract"),
    "RF401": ("error",
              "coroutine (async def) cannot be a remote entry point — its "
              "result is a coroutine object, not a wire-serializable value"),
    "RF402": ("warning",
              "blocking call inside a coroutine stalls the event loop "
              "serving it"),
}


class ShippabilityWarning(UserWarning):
    """Deploy-time analyzer finding on a function about to ship."""


class AnalysisError(RuntimeError):
    """Strict-mode deploy rejection; carries the full diagnostic list."""

    def __init__(self, function: str, diagnostics: Iterable["Diagnostic"]):
        self.function = function
        self.diagnostics = tuple(diagnostics)
        lines = "\n".join("  " + d.format() for d in self.diagnostics)
        super().__init__(
            f"function {function!r} rejected by shippability analysis "
            f"({len(self.diagnostics)} diagnostic(s)):\n{lines}")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str                  # "RF101"
    severity: str              # error | warning | info
    message: str               # human sentence, names the offending symbol
    symbol: str = ""           # offending name (global, capture, method)
    function: str = ""         # qualname of the function the finding is in
    file: str = ""             # co_filename
    line: int = 0              # source line the pattern matched on

    def format(self) -> str:
        """``file:line: RFxxx severity: message [in function]`` — the
        compiler-style one-liner."""
        loc = f"{self.file}:{self.line}: " if self.file else ""
        where = f" [in {self.function}]" if self.function else ""
        return f"{loc}{self.code} {self.severity}: {self.message}{where}"

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Diagnostic":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def make(code: str, message: str, *, symbol: str = "", function: str = "",
         file: str = "", line: int = 0,
         severity: str | None = None) -> Diagnostic:
    """Build a diagnostic with the rule's registered default severity."""
    sev = severity or RULES[code][0]
    return Diagnostic(code=code, severity=sev, message=message, symbol=symbol,
                      function=function, file=file, line=line)
