"""Deploy-time shippability analysis (ISSUE 9).

Cppless's LLVM extension validates remote function objects *at compile
time*: a function that cannot ship is a compiler error, not a runtime
surprise (paper §3).  This package is the Python analogue — a static pass
that walks a function object exactly the way :mod:`repro.core.codeship`
freezes it (bytecode + closure graph, recursing through callable
captures) and emits compiler-style diagnostics with stable rule codes,
severities, and ``file:line`` source locations.

Every rule mirrors a *real* runtime failure mode of the existing stack:

* **RF1xx shippability** — the function would raise ``NameError`` under
  ``_thaw_globals``'s fresh-globals contract, or a capture cannot cross
  the wire.
* **RF2xx semantics** — writes to captures/globals that by-value shipping
  silently turns into lost writes.
* **RF3xx invariance** — nondeterminism (``random``/``uuid``/wall-clock)
  that breaks the repo's batch-composition bit-identity contract.
* **RF4xx async/serving** — coroutine entry points and blocking calls
  inside coroutines submitted through ``AsyncSession``.

Entry points:

* :func:`analyze_function` — full-fidelity runtime analysis (capture
  values available); run by ``Deployment`` at deploy time.
* :func:`analyze_code` — static analysis of a bare code object (no
  capture values); the CLI path, which never executes the linted file.
* ``python -m repro.analysis <module-or-path> ...`` — offline linter over
  ``@session.remote`` / ``session.function`` call sites.
"""
from .diagnostics import (AnalysisError, Diagnostic, RULES,
                          ShippabilityWarning, SEVERITIES)
from .analyzer import (analyze_code, analyze_function, attach_failure_hint,
                       match_diagnostics)

__all__ = [
    "AnalysisError", "Diagnostic", "RULES", "SEVERITIES",
    "ShippabilityWarning", "analyze_code", "analyze_function",
    "attach_failure_hint", "match_diagnostics",
]
