"""The static pass: bytecode + closure-graph walk behind every rule.

``analyze_function`` walks a *live* function object the same way
``freeze_function`` ships it — the same importability test, the same
capture classification, the same recursion through callable captures — so
a diagnostic here is a prediction about exactly the artifact that would
cross the wire.  ``analyze_code`` is the value-free subset used by the CLI
(which compiles source without executing it): bytecode rules only, no
capture probes.

Bytecode is scanned with :mod:`dis` in an opcode-version-tolerant way
(3.10 ``LOAD_METHOD``/``CALL_FUNCTION`` and 3.11+ ``LOAD_ATTR``/``CALL``
both match); source locations come from ``co_filename`` plus the
instruction line, so diagnostics point at the offending *statement*, not
just the ``def``.
"""
from __future__ import annotations

import builtins
import dis
import re
import types
from typing import Any, Callable, Iterable

from .diagnostics import Diagnostic, make

__all__ = ["analyze_function", "analyze_code", "attach_failure_hint",
           "match_diagnostics"]

_BUILTINS = frozenset(dir(builtins)) | {"__build_class__", "__import__"}

# Opcode-stream noise to skip when looking at neighbouring instructions.
_TRANSPARENT = frozenset({"CACHE", "PRECALL", "EXTENDED_ARG", "NOP",
                          "RESUME", "PUSH_NULL", "COPY_FREE_VARS"})
_ATTR_OPS = frozenset({"LOAD_ATTR", "LOAD_METHOD"})
_CALL_OPS = frozenset({"CALL", "CALL_FUNCTION", "CALL_METHOD",
                       "CALL_FUNCTION_KW", "CALL_FUNCTION_EX", "CALL_KW"})

# RF203: method names that mutate their receiver (best-effort, the
# documented opcode-pattern subset).
_MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "clear", "sort",
    "reverse", "add", "discard", "update", "setdefault", "popitem",
    "appendleft", "extendleft", "popleft", "write", "writelines", "put",
})

# RF301: nondeterminism sources.  ``jax.random`` (explicit keys) and
# ``np.random.default_rng(seed)`` are deterministic and deliberately NOT
# matched: only *bare* loads of these module names, the ``os``/``time``
# attributes below, and seedless legacy numpy samplers are flagged.
_NONDET_MODULES = frozenset({"random", "uuid", "secrets"})
_NONDET_ATTRS = {"os": frozenset({"urandom", "getrandom"}),
                 "time": frozenset({"time", "time_ns"})}
_NP_SAMPLERS = frozenset({
    "random", "rand", "randn", "randint", "random_sample", "normal",
    "uniform", "shuffle", "choice", "permutation", "standard_normal",
    "bytes", "seed",
})
_NP_NAMES = frozenset({"np", "numpy"})

_CO_COROUTINE = 0x0080 | 0x0200      # CO_COROUTINE | CO_ASYNC_GENERATOR


def _line_of(instr) -> int | None:
    line = getattr(instr, "starts_line", None)
    if line is None:
        pos = getattr(instr, "positions", None)
        line = getattr(pos, "lineno", None) if pos is not None else None
    return line


def _significant(instrs: list, i: int, step: int) -> Any:
    """Nearest non-noise instruction from ``i`` in direction ``step``."""
    j = i + step
    while 0 <= j < len(instrs):
        if instrs[j].opname not in _TRANSPARENT:
            return instrs[j]
        j += step
    return None


def _importable_ref(obj: Any) -> bool:
    """Mirror of ``codeship._importable`` — module:qualname round-trips."""
    from ..core.codeship import _importable
    return _importable(obj)


def _scan_code(code: types.CodeType, *, main_like: bool,
               captures: frozenset, func_name: str,
               globals_map: dict | None, is_coro: bool,
               out: list[Diagnostic], seen: set) -> None:
    """One code object: RF101 / RF2xx / RF301 / RF402 + nested recursion."""
    if id(code) in seen:
        return
    seen.add(id(code))
    file = code.co_filename
    instrs = list(dis.get_instructions(code))
    coro = is_coro or bool(code.co_flags & _CO_COROUTINE)

    stored_globals = {i.argval for i in instrs if i.opname == "STORE_GLOBAL"}
    emitted: set[tuple] = set()

    def emit(rule: str, msg: str, symbol: str, line: int, **kw) -> None:
        key = (rule, symbol, func_name)
        if key in emitted:
            return
        emitted.add(key)
        out.append(make(rule, msg, symbol=symbol, function=func_name,
                        file=file, line=line, **kw))

    # local aliases bound by in-body imports: var -> module name, and
    # var -> (module, attr) for ``from m import a [as b]``
    local_modules: dict[str, str] = {}
    local_attrs: dict[str, tuple[str, str]] = {}
    pending_import: str | None = None
    pending_from: tuple[str, str] | None = None

    line = code.co_firstlineno
    for i, instr in enumerate(instrs):
        l = _line_of(instr)
        if l is not None:
            line = l
        op, val = instr.opname, instr.argval

        # ---- import-alias tracking -----------------------------------
        if op == "IMPORT_NAME":
            pending_import, pending_from = val, None
            continue
        if op == "IMPORT_FROM":
            pending_from = (pending_import or "", val)
            continue
        if op in ("STORE_FAST", "STORE_NAME", "STORE_DEREF") and (
                pending_import is not None or pending_from is not None):
            if pending_from is not None:
                local_attrs[val] = pending_from
                pending_from = None        # next IMPORT_FROM re-arms
            else:
                local_modules[val] = pending_import or ""
                pending_import = None
            if op != "STORE_DEREF":
                continue                   # fall through for capture check
        elif op not in ("IMPORT_FROM",):
            # any other instruction ends a bare ``import m`` sequence
            if op not in ("STORE_FAST", "STORE_NAME"):
                pending_import = pending_import if op == "POP_TOP" else None

        nxt = _significant(instrs, i, +1)
        prv = _significant(instrs, i, -1)

        # ---- RF101: unresolvable global under fresh worker globals ----
        if op == "LOAD_GLOBAL" and main_like:
            if val not in _BUILTINS and val not in stored_globals:
                emit("RF101",
                     f"global {val!r} will not resolve on the worker: "
                     f"'__main__'/script-defined functions are rebuilt with "
                     f"fresh globals (import or define {val!r} inside the "
                     f"function body, or move the function to an importable "
                     f"module)", val, line)

        # ---- RF202: global writes -------------------------------------
        if op in ("STORE_GLOBAL", "DELETE_GLOBAL"):
            emit("RF202",
                 f"write to global {val!r} happens in the worker's copy of "
                 f"the module and never reaches the client (return the "
                 f"value instead)", val, line)

        # ---- RF201: capture writes ------------------------------------
        if op in ("STORE_DEREF", "DELETE_DEREF") and val in captures:
            emit("RF201",
                 f"write to captured variable {val!r} is a lost write: "
                 f"captures ship by value, so the client's {val!r} never "
                 f"sees it (return the new value instead)", val, line)

        # ---- RF203: mutation of captured objects ----------------------
        if op in _ATTR_OPS and val in _MUTATORS and prv is not None and \
                prv.opname == "LOAD_DEREF" and prv.argval in captures:
            emit("RF203",
                 f"{prv.argval!r}.{val}() mutates a worker-side copy of "
                 f"the capture; the client's object is unchanged",
                 f"{prv.argval}.{val}", line)
        if op == "STORE_ATTR" and prv is not None and \
                prv.opname == "LOAD_DEREF" and prv.argval in captures:
            emit("RF203",
                 f"attribute assignment on captured {prv.argval!r} mutates "
                 f"a worker-side copy; the client's object is unchanged",
                 f"{prv.argval}.{val}", line)
        if op in ("STORE_SUBSCR", "DELETE_SUBSCR"):
            # value, obj, index on the stack: the receiver load sits a few
            # instructions back — best-effort window scan
            k, hops = i, 0
            while hops < 4:
                p = _significant(instrs, k, -1)
                if p is None:
                    break
                k = instrs.index(p)
                hops += 1
                if p.opname == "LOAD_DEREF" and p.argval in captures:
                    emit("RF203",
                         f"item assignment on captured {p.argval!r} mutates "
                         f"a worker-side copy; the client's object is "
                         f"unchanged", f"{p.argval}[]", line)
                    break
                if p.opname in ("LOAD_FAST", "LOAD_GLOBAL", "LOAD_NAME"):
                    break          # receiver is local/global, not a capture

        # ---- RF301: nondeterminism ------------------------------------
        if op == "LOAD_GLOBAL" and val in _NONDET_MODULES:
            g = None if globals_map is None else globals_map.get(val)
            genuine = (globals_map is None
                       or (isinstance(g, types.ModuleType)
                           and g.__name__ in _NONDET_MODULES))
            if genuine:
                emit("RF301",
                     f"call into {val!r} is nondeterministic: repeated "
                     f"invocations of the same payload return different "
                     f"results, breaking the bit-identity invariance "
                     f"contract (thread an explicit seed/key through the "
                     f"payload instead)", val, line,
                     )
        if op == "LOAD_GLOBAL" and val in _NONDET_ATTRS and nxt is not None \
                and nxt.opname in _ATTR_OPS \
                and nxt.argval in _NONDET_ATTRS[val]:
            emit("RF301",
                 f"{val}.{nxt.argval}() is nondeterministic across "
                 f"invocations, breaking the bit-identity invariance "
                 f"contract", f"{val}.{nxt.argval}", line)
        if op == "LOAD_GLOBAL" and val in _NP_NAMES and nxt is not None and \
                nxt.opname in _ATTR_OPS and nxt.argval == "random":
            n2 = _significant(instrs, instrs.index(nxt), +1)
            if n2 is not None and n2.opname in _ATTR_OPS and \
                    n2.argval in _NP_SAMPLERS:
                emit("RF301",
                     f"{val}.random.{n2.argval} uses numpy's seedless "
                     f"global RNG; use np.random.default_rng(seed) or "
                     f"jax.random with an explicit key",
                     f"{val}.random.{n2.argval}", line)
        if op == "LOAD_FAST" and val in local_modules and \
                local_modules[val] in _NONDET_MODULES and \
                nxt is not None and nxt.opname in _ATTR_OPS:
            emit("RF301",
                 f"{val}.{nxt.argval}() (from in-body 'import "
                 f"{local_modules[val]}') is nondeterministic, breaking "
                 f"the bit-identity invariance contract",
                 f"{local_modules[val]}.{nxt.argval}", line)
        if op == "LOAD_FAST" and val in local_attrs and \
                local_attrs[val][0] in _NONDET_MODULES and \
                nxt is not None and nxt.opname in _CALL_OPS:
            mod, attr = local_attrs[val]
            emit("RF301",
                 f"{attr}() (from in-body 'from {mod} import {attr}') is "
                 f"nondeterministic, breaking the bit-identity invariance "
                 f"contract", f"{mod}.{attr}", line)

        # ---- RF402: blocking calls inside coroutines ------------------
        if coro:
            if op == "LOAD_GLOBAL" and val == "time" and nxt is not None \
                    and nxt.opname in _ATTR_OPS and nxt.argval == "sleep":
                emit("RF402",
                     "time.sleep() inside a coroutine blocks the event "
                     "loop serving every other request (use 'await "
                     "asyncio.sleep(...)')", "time.sleep", line)
            if op == "LOAD_FAST" and local_modules.get(val) == "time" and \
                    nxt is not None and nxt.opname in _ATTR_OPS and \
                    nxt.argval == "sleep":
                emit("RF402",
                     "time.sleep() inside a coroutine blocks the event "
                     "loop serving every other request (use 'await "
                     "asyncio.sleep(...)')", "time.sleep", line)
            if op == "LOAD_FAST" and local_attrs.get(val) == \
                    ("time", "sleep") and nxt is not None and \
                    nxt.opname in _CALL_OPS:
                emit("RF402",
                     "time.sleep() inside a coroutine blocks the event "
                     "loop serving every other request (use 'await "
                     "asyncio.sleep(...)')", "time.sleep", line)

    # ---- nested code objects (comprehensions, inner defs) -------------
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            _scan_code(const, main_like=main_like,
                       captures=captures & frozenset(const.co_freevars),
                       func_name=f"{func_name}.{const.co_name}"
                       if const.co_name != func_name else func_name,
                       globals_map=globals_map, is_coro=False,
                       out=out, seen=seen)


# ---------------------------------------------------------------- host-only

def _host_only_reason(v: Any) -> str | None:
    """Why a capture can never leave this process, or ``None``."""
    import io
    import socket
    import subprocess
    import threading

    t = type(v)
    if t.__module__ == "_thread":
        return "a thread lock"
    if isinstance(v, (threading.Event, threading.Condition,
                      threading.Semaphore, threading.Thread,
                      threading.Barrier)):
        return f"a threading.{t.__name__}"
    if isinstance(v, io.IOBase):
        return "an open file handle"
    if isinstance(v, socket.socket):
        return "a socket"
    if isinstance(v, subprocess.Popen):
        return "a subprocess handle"
    if isinstance(v, (types.GeneratorType, types.CoroutineType,
                      types.AsyncGeneratorType)):
        return f"a live {t.__name__}"
    if isinstance(v, memoryview):
        return "a memoryview over host memory"
    if t.__module__.startswith("repro.") and t.__name__ in (
            "Session", "AsyncSession", "Dispatcher", "DispatcherInstance",
            "Deployment", "BoundFunction", "AsyncBoundFunction",
            "InvocationFuture", "AsyncInvocation", "ContinuousBatcher",
            "FleetRouter", "LMServer", "EngineClient"):
        return f"a client-side repro.{t.__name__} (backends, sessions and " \
               f"futures never ship)"
    return None


def _probe_serialize(v: Any) -> str | None:
    """Dry-run the wire serializer on one capture; error text on failure.

    Known-leaf types short-circuit without encoding — a multi-GB params
    array should not be serialized twice per deploy just to prove it can
    be.  Only compound/unknown values pay for the real dry run.
    """
    if v is None or isinstance(v, (int, float, bool, str, bytes)):
        return None
    import numpy as np
    if isinstance(v, (np.ndarray, np.generic)):
        return None
    try:
        import jax
        if isinstance(v, jax.Array):
            return None
    except Exception:
        pass
    try:
        from ..serialization.artifacts import ArtifactRef
        if isinstance(v, ArtifactRef):
            return None
    except Exception:
        pass
    from ..serialization import serialize
    try:
        serialize(v)
        return None
    except Exception as e:
        return str(e) or type(e).__name__


# ------------------------------------------------------------- entry points

def _unwrap(fn: Any) -> Callable:
    """Accept plain callables, ``RemoteFunction``s and bound handles."""
    rf = getattr(fn, "_rf", None)          # cloud.BoundFunction
    if rf is not None:
        fn = rf
    inner = getattr(fn, "fn", None)        # core.RemoteFunction
    if inner is not None and callable(inner) and hasattr(inner, "__code__"):
        return inner
    return fn


def _main_like(module: str | None) -> bool:
    """Would ``_thaw_globals`` hand this code fresh globals?"""
    if not module or module == "__main__":
        return True
    import importlib.util
    try:
        return importlib.util.find_spec(module) is None
    except (ImportError, ValueError):
        return True


def analyze_code(code: types.CodeType, *, module: str | None = "__main__",
                 qualname: str | None = None,
                 is_coroutine: bool | None = None) -> list[Diagnostic]:
    """Value-free analysis of a bare code object (the CLI path).

    No capture values are available, so RF102/RF103/RF104 cannot fire —
    the bytecode rules (RF101/RF2xx/RF301/RF4xx) still do.  ``module``
    decides the fresh-globals question: ``"__main__"``/``None`` (scripts)
    arms RF101, an importable module name disarms it.
    """
    out: list[Diagnostic] = []
    name = qualname or code.co_name
    coro = bool(code.co_flags & _CO_COROUTINE) if is_coroutine is None \
        else is_coroutine
    if coro:
        out.append(make(
            "RF401",
            f"{name!r} is a coroutine function: invoking it remotely "
            f"returns a coroutine object, which is not wire-serializable "
            f"(make the remote function sync; drive it *through* "
            f"AsyncSession instead)",
            symbol=name, function=name, file=code.co_filename,
            line=code.co_firstlineno))
    _scan_code(code, main_like=_main_like(module),
               captures=frozenset(code.co_freevars), func_name=name,
               globals_map=None, is_coro=coro, out=out, seen=set())
    return out


def analyze_function(fn: Callable, *, name: str | None = None,
                     cross_process: bool = True,
                     _seen: set | None = None) -> list[Diagnostic]:
    """Full-fidelity analysis of a live function object.

    Walks exactly what ``freeze_function`` would ship: the importability
    test, each capture cell (classified the same way: module / code /
    importable ref / payload slot), and recursion through callable
    captures that would be frozen into the artifact.  ``cross_process=
    False`` (in-process backends execute the client's own function
    object) downgrades RF101 to ``info`` — the finding only bites when
    code actually ships.
    """
    fn = _unwrap(fn)
    seen = _seen if _seen is not None else set()
    out: list[Diagnostic] = []
    code = getattr(fn, "__code__", None)
    disp = name or getattr(fn, "__qualname__", None) \
        or getattr(fn, "__name__", repr(fn))

    if code is None:
        # non-function callable as the entry itself: importable → fine;
        # else analyze its __call__ if it has python code
        if _importable_ref(fn):
            return out
        call = getattr(type(fn), "__call__", None)
        if getattr(call, "__code__", None) is not None:
            return analyze_function(call, name=f"{disp}.__call__",
                                    cross_process=cross_process, _seen=seen)
        return out
    if id(code) in seen:
        return out

    module = getattr(fn, "__module__", None)
    shipped_as_ref = _importable_ref(fn)
    main_like = (not shipped_as_ref) and _main_like(module)

    if code.co_flags & _CO_COROUTINE:
        out.append(make(
            "RF401",
            f"{disp!r} is a coroutine function: invoking it remotely "
            f"returns a coroutine object, which is not wire-serializable "
            f"(make the remote function sync; drive it *through* "
            f"AsyncSession instead)",
            symbol=disp, function=disp, file=code.co_filename,
            line=code.co_firstlineno))

    _scan_code(code, main_like=main_like,
               captures=frozenset(code.co_freevars), func_name=disp,
               globals_map=getattr(fn, "__globals__", None),
               is_coro=bool(code.co_flags & _CO_COROUTINE),
               out=out, seen=seen)

    # ---- capture graph, classified exactly like freeze_function --------
    names = code.co_freevars
    cells = fn.__closure__ or ()
    file, line = code.co_filename, code.co_firstlineno
    for cname, cell in zip(names, cells):
        try:
            v = cell.cell_contents
        except ValueError:
            continue                        # self-reference: payload slot
        if isinstance(v, types.ModuleType):
            if v.__name__ in _NONDET_MODULES:
                out.append(make(
                    "RF301",
                    f"captured module {v.__name__!r} is a nondeterminism "
                    f"source; thread explicit seeds through the payload",
                    symbol=cname, function=disp, file=file, line=line))
            continue
        if callable(v) and getattr(v, "__code__", None) is not None:
            if not _importable_ref(v):      # frozen into the artifact
                out.extend(analyze_function(
                    v, name=f"{disp} capture {cname!r}",
                    cross_process=cross_process, _seen=seen))
            continue
        if callable(v) and _importable_ref(v):
            continue                        # ships as module:qualname ref
        reason = _host_only_reason(v)
        if reason is not None:
            out.append(make(
                "RF102",
                f"capture {cname!r} is {reason}: it exists only in this "
                f"process and cannot ship to a worker (open/acquire the "
                f"resource inside the function body instead)",
                symbol=cname, function=disp, file=file, line=line))
            continue
        probe_err = _probe_serialize(v)
        if probe_err is not None:
            kind = "callable " if callable(v) else ""
            out.append(make(
                "RF103",
                f"{kind}capture {cname!r} ({type(v).__name__}) failed the "
                f"wire-serialization dry run: {probe_err}",
                symbol=cname, function=disp, file=file, line=line))
            continue
        if callable(v):
            out.append(make(
                "RF104",
                f"capture {cname!r} ({type(v).__name__}) is callable but "
                f"has no __code__ and no importable ref: it ships by "
                f"value in the payload, not as code",
                symbol=cname, function=disp, file=file, line=line))

    if not cross_process:
        out = [d if d.code != "RF101"
               else Diagnostic(**{**d.to_json(), "severity": "info"})
               for d in out]
    return out


# -------------------------------------------------- runtime failure hints

_NAME_RE = re.compile(r"name '([^']+)' is not defined")
_SERIAL_HINTS = ("serializ", "register_custom", "wire-serializable",
                 "not registered", "pickle", "marshal")


def match_diagnostics(exc: BaseException,
                      diags: Iterable[Diagnostic]) -> list[Diagnostic]:
    """Diagnostics that plausibly explain a remote failure.

    ``NameError`` matches RF101 on the missing symbol; serialization
    failures match the capture rules (RF102/RF103/RF104); code-shipping
    failures match all RF1xx.  Anything else gets no hint — a wrong hint
    is worse than none.
    """
    diags = list(diags or ())
    text = f"{type(exc).__name__}: {exc} " \
           f"{getattr(exc, 'remote_traceback', '')}"
    if "NameError" in text or isinstance(exc, NameError):
        m = _NAME_RE.search(text)
        if m:
            hits = [d for d in diags
                    if d.code == "RF101" and d.symbol == m.group(1)]
            if hits:
                return hits
        return [d for d in diags if d.code == "RF101"]
    low = text.lower()
    if any(h in low for h in _SERIAL_HINTS):
        hits = [d for d in diags
                if d.code in ("RF102", "RF103", "RF104", "RF401")]
        if hits:
            return hits
    if "code artifact" in low or "codeshiperror" in low or \
            "cannot freeze" in low:
        return [d for d in diags if d.code.startswith("RF1")]
    return []


def attach_failure_hint(exc: BaseException, deployed: Any) -> bool:
    """Append a "likely cause" analysis hint to a remote failure.

    Called from the transport completion path when a worker-side error
    comes back: re-uses the deploy-time diagnostics when the deployment
    recorded them (the common case), re-runs the analyzer on the client's
    function object otherwise.  The hint lands in two places: an
    ``analysis_hint`` attribute (picked up as the ``error.analysis`` span
    attribute) and appended to ``remote_traceback`` so plain tracebacks
    show it too.  Returns whether a hint was attached.
    """
    diags = getattr(deployed, "diagnostics", None)
    if diags is None:
        rf = getattr(deployed, "remote_fn", None)
        fn = getattr(rf, "fn", None) or deployed
        try:
            diags = analyze_function(fn)
        except Exception:
            return False
    hits = match_diagnostics(exc, diags)
    if not hits:
        return False
    hint = "\n".join("likely cause: " + d.format() for d in hits)
    exc.analysis_hint = hint                       # type: ignore[attr-defined]
    rtb = getattr(exc, "remote_traceback", "") or ""
    sep = "\n" if rtb and not rtb.endswith("\n") else ""
    exc.remote_traceback = (                       # type: ignore[attr-defined]
        f"{rtb}{sep}[repro.analysis] {hint}")
    return True
