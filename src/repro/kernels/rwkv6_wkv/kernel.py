"""Pallas TPU kernel for the chunked RWKV-6 WKV recurrence.

One grid cell = one (batch, head, chunk); chunk dim minor-most/sequential,
(K, V) state in VMEM scratch.  Unlike SSD (scalar decay per head), RWKV-6
decays *per key channel*, so the intra-chunk pairwise term is a K-reduction
of an elementwise product — VPU work over an (L, L, K) tile rather than an
MXU matmul.  That bounds the chunk: L=64, K=64 → 64³·4 B = 1 MiB in VMEM.
All exponentials are differences of cumulative log-decays (≤ 0), so the
kernel is overflow-free in fp32 at any chunk length.

Layout: r/k/v/logw (B, H, S, K); u (H, K); s0 (B, H, K, V).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                y_ref, sout_ref, state_ref, *, nchunks, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)        # log decay <= 0
    u = u_ref[0].astype(jnp.float32)             # (K,)

    cum = jnp.cumsum(lw, axis=0)                 # (L, K)
    cex = cum - lw                               # cum at t-1

    # intra-chunk pairwise: A[t,s] = sum_k r[t]k[s]exp(cex[t]-cum[s]), s<t
    diff = cex[:, None, :] - cum[None, :, :]     # (L, L, K) <= 0 for s<t
    strict = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
              > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    pair = jnp.exp(jnp.where(strict[..., None], diff, -jnp.inf))
    A = jnp.sum(r[:, None, :] * k[None, :, :] * pair, axis=-1)   # (L, L)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v  # u bonus

    # inter-chunk: read carried-in state through exp(cum[t-1])
    S = state_ref[...]                           # (K, V)
    y += jax.lax.dot_general(r * jnp.exp(cex), S, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S <- exp(cum[-1]) S + (k ⊙ exp(cum[-1]-cum))^T v
    k_dec = k * jnp.exp(cum[-1:] - cum)
    state_ref[...] = (jnp.exp(cum[-1])[:, None] * S
                      + jax.lax.dot_general(
                          k_dec, v, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(ic == nchunks - 1)
    def _fin():
        sout_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked_pallas(r, k, v, logw, u, s0, *, chunk: int = 64,
                        interpret: bool = False):
    """r/k/v/logw (B,H,S,K); u (H,K); s0 (B,H,K,V) ->
    y (B,H,S,V), s_final (B,H,K,V)."""
    b, h, s, kk = r.shape
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_wkv_kernel, nchunks=nc, chunk=chunk)
    y, sout = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, kk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, kk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, kk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, kk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, kk), lambda b_, h_, c: (h_, 0)),
            pl.BlockSpec((1, 1, kk, kk), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, kk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, kk, kk), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, kk), r.dtype),
            jax.ShapeDtypeStruct((b, h, kk, kk), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y, sout
