"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head (K key channels, V value channels), with data-dependent decay:

  S_t = diag(w_t) S_{t-1} + k_t^T v_t          state (K, V)
  o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      current token gets bonus u

Shapes: r, k, v (B, S, H, K) (K == V); logw (B, S, H, K) = log w_t <= 0
(models pass -exp(w_proj), never materializing w to keep exp() composition
stable); u (H, K); s0 (B, H, K, V).

`wkv6_scan_ref` — exact sequential oracle.
`wkv6_chunked`  — parallel chunked form; all exponentials are differences of
cumulative log-decays within a chunk, so every term is <= 1 (no overflow; the
GLA-style k/cumw split would overflow in fp32 at chunk 64).  Mirrors the
Pallas kernel blocking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan_ref(r, k, v, logw, u, s0=None):
    b, s, h, kk = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, t):
        rt, kt, vt, wt = t                                  # (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[..., :, None] * kv)
        Snew = wt[..., :, None] * S + kv
        return Snew, o

    Sinit = (jnp.zeros((b, h, kk, kk), jnp.float32) if s0 is None
             else s0.astype(jnp.float32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, w))
    Slast, os = jax.lax.scan(step, Sinit, xs)
    return jnp.moveaxis(os, 0, 1).astype(r.dtype), Slast


def wkv6_chunked(r, k, v, logw, u, s0=None, *, chunk: int = 64):
    b, s, h, kk = r.shape
    assert s % chunk == 0, (s, chunk)
    nc, L = s // chunk, chunk
    rf = r.astype(jnp.float32).reshape(b, nc, L, h, kk)
    kf = k.astype(jnp.float32).reshape(b, nc, L, h, kk)
    vf = v.astype(jnp.float32).reshape(b, nc, L, h, kk)
    lw = logw.astype(jnp.float32).reshape(b, nc, L, h, kk)
    uf = u.astype(jnp.float32)

    cum = jnp.cumsum(lw, axis=2)                  # (B,nc,L,H,K) decreasing
    cex = cum - lw                                # cum at t-1

    # ---- intra-chunk: A[t,s] = sum_k r_t k_s exp(cum[t-1]-cum[s]), s < t.
    # Mask BEFORE exp (s >= t gives positive exponents -> inf, and inf*0
    # NaNs the backward pass).
    diff = cex[:, :, :, None] - cum[:, :, None]   # (B,nc,Lt,Ls,H,K)
    strict = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])
    pair = jnp.exp(jnp.where(strict[None, None, :, :, None, None],
                             diff, -jnp.inf))
    A = jnp.einsum("bcthk,bcshk,bctshk->bctsh", rf, kf, pair)
    diag = jnp.einsum("bcthk,hk,bcthk->bcth", rf, uf, kf)  # u bonus at t==s
    y_intra = jnp.einsum("bctsh,bcshv->bcthv", A, vf)
    y_intra += diag[..., None] * vf

    # ---- inter-chunk: carried-in state read out through exp(cum[t-1])
    r_dec = rf * jnp.exp(cex)                     # (B,nc,L,H,K)

    # per-chunk state ingredients
    w_end = jnp.exp(cum[:, :, -1:] - cum)         # (B,nc,L,H,K) <= 1
    k_dec = kf * w_end
    chunk_kv = jnp.einsum("bcshk,bcshv->bchkv", k_dec, vf)
    chunk_decay = jnp.exp(cum[:, :, -1])          # (B,nc,H,K)

    def step(S, t):
        ckv, cd = t
        return cd[..., None] * S + ckv, S         # emit state *before* chunk

    Sinit = (jnp.zeros((b, h, kk, kk), jnp.float32) if s0 is None
             else s0.astype(jnp.float32))
    Slast, Sprevs = jax.lax.scan(
        step, Sinit, (jnp.moveaxis(chunk_kv, 1, 0),
                      jnp.moveaxis(chunk_decay, 1, 0)))
    Sprevs = jnp.moveaxis(Sprevs, 0, 1)           # (B,nc,H,K,V)
    y_inter = jnp.einsum("bcthk,bchkv->bcthv", r_dec, Sprevs)

    y = (y_intra + y_inter).reshape(b, s, h, kk).astype(r.dtype)
    return y, Slast


def wkv6_decode_ref(rt, kt, vt, logwt, u, S):
    """One token: rt/kt/vt/logwt (B,H,K); S (B,H,K,V) -> (o (B,H,V), Snew)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (rt, kt, vt))
    w = jnp.exp(logwt.astype(jnp.float32))
    kv = kf[..., :, None] * vf[..., None, :]
    o = jnp.einsum("bhk,bhkv->bhv", rf,
                   S + u.astype(jnp.float32)[..., :, None] * kv)
    Snew = w[..., :, None] * S + kv
    return o.astype(rt.dtype), Snew
