"""Public WKV6 op: layout handling, padding, impl dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import wkv6_chunked_pallas
from .ref import wkv6_chunked, wkv6_decode_ref, wkv6_scan_ref


def wkv6(r, k, v, logw, u, s0=None, *, chunk: int = 64,
         impl: str = "chunked"):
    """RWKV-6 WKV.  r/k/v/logw (B,S,H,K); u (H,K); s0 (B,H,K,V) or None ->
    (o (B,S,H,V), s_final (B,H,K,V)).

    impl: "scan" (exact oracle) | "chunked" (XLA path) | "pallas" |
    "pallas_interpret".
    """
    b, s, h, kk = r.shape
    if impl == "scan":
        return wkv6_scan_ref(r, k, v, logw, u, s0)

    pad = (-s) % chunk
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, widths)
        k = jnp.pad(k, widths)          # k=0 padding: no state contribution
        v = jnp.pad(v, widths)
        logw = jnp.pad(logw, widths)    # logw=0 => w=1: state passes through

    if impl == "chunked":
        o, sl = wkv6_chunked(r, k, v, logw, u, s0, chunk=chunk)
        return o[:, :s], sl

    interpret = impl == "pallas_interpret"
    if s0 is None:
        s0 = jnp.zeros((b, h, kk, kk), jnp.float32)
    rt, kt, vt, lwt = (jnp.swapaxes(t, 1, 2) for t in (r, k, v, logw))
    o, sl = wkv6_chunked_pallas(rt, kt, vt, lwt, u, s0, chunk=chunk,
                                interpret=interpret)
    return jnp.swapaxes(o, 1, 2)[:, :s], sl


wkv6_decode = wkv6_decode_ref
