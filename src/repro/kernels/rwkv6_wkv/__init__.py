from .ops import wkv6, wkv6_decode
from .ref import wkv6_chunked, wkv6_decode_ref, wkv6_scan_ref
