"""Public attention op: layout handling, padding, impl dispatch.

``attention(q, k, v)`` takes the model-native layout (B, S, H, D) and
dispatches to the Pallas kernel (TPU target; ``interpret=True`` executes the
kernel body on CPU) or the pure-jnp oracle in ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd
from .ref import attention_ref, attention_xla


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: int = 0, scale: float | None = None,
              kv_len=None, kv_start=None, impl: str = "ref",
              block_q: int = 128, block_k: int = 128):
    """q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D) -> (B,Sq,Hq,D).

    ``kv_start`` (B,) int32: per-row left-pad count — kv positions < start
    are masked out on every impl (ragged-batch prefill).  A fully masked
    row (start == Skv) yields finite output, never NaN.

    impl: "ref" (jnp oracle) | "pallas" (TPU) | "pallas_interpret" (CPU
    execution of the kernel body, used by the allclose test sweeps).
    """
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale, kv_len=kv_len,
                             kv_start=kv_start)
    if impl == "xla":
        return attention_xla(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale, kv_len=kv_len,
                             kv_start=kv_start)
    if kv_len is not None:
        # the Pallas prefill kernel has no kv_len operand (chunked prefill
        # runs on the xla path today); fall back to the oracle
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale, kv_len=kv_len,
                             kv_start=kv_start)

    interpret = impl == "pallas_interpret"
    b, sq, hq, d = q.shape
    bq = min(block_q, max(16, sq))
    bk = min(block_k, max(16, k.shape[1]))

    qt = jnp.swapaxes(q, 1, 2)                    # (B,Hq,Sq,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qt, sq0 = _pad_to(qt, 2, bq)
    kt, _ = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)

    out = flash_attention_bhsd(qt, kt, vt, kv_start, causal=causal,
                               window=window, q_offset=q_offset, scale=scale,
                               block_q=bq, block_k=bk, interpret=interpret)
    out = out[:, :, :sq0]
    return jnp.swapaxes(out, 1, 2)
