"""Pallas TPU flash attention (tiled, causal/windowed, GQA).

Layout: q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D).  Grid (B, Hq, Sq/bq,
Skv/bk) — the kv-block dim is minor-most, so it iterates sequentially on TPU
and the running softmax state (acc, m, l) lives in VMEM scratch across kv
blocks.  Fully-masked kv blocks are skipped with ``pl.when`` (causal upper
triangle and out-of-window lower band), so the causal pass does ~half the
work — the roofline win the paper's tiling (32x32 -> 16x16, Fig 1) chases.

Block sizes default to 128 (MXU-aligned); D is kept whole per block
(64..256 for the assigned archs — fits VMEM comfortably:
3 * 128 * 256 * 4 B < 0.5 MiB working set per operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _fa_kernel(start_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, scale, causal, window, q_offset, block_q, block_k,
               kv_blocks, kv_valid):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    kv_start = start_ref[0, 0]              # left-pad count for this row

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute coordinates of this tile
    row0 = iq * block_q + q_offset          # first absolute q position
    col0 = ik * block_k

    # tile-level skip: causal upper triangle / sliding-window lower band /
    # left-pad prefix tiles
    live = col0 < kv_valid                  # beyond valid kv (padding) tile
    live &= col0 + block_k > kv_start       # tile fully inside the left pad
    if causal:
        live &= col0 <= row0 + block_q - 1
    if window:
        live &= col0 + block_k - 1 > row0 - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (cols < kv_valid) & (cols >= kv_start)
        if causal:
            mask &= cols <= rows
        if window:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # a q row with NO valid col so far (m_new == NEG_INF: a pad query
        # sharing a live tile with real rows) must contribute 0, not
        # exp(NEG_INF - NEG_INF) = 1 per col — keeps l at 0 so _fin zeroes it
        p = jnp.where((m_new > 0.5 * NEG_INF)[:, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ik == kv_blocks - 1)
    def _fin():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)     # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "scale",
                              "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, kv_start=None, *, causal=True, window=0,
                         q_offset=0, scale=None, block_q=128, block_k=128,
                         interpret=False):
    """q (B,Hq,Sq,D); k,v (B,Hkv,Skv,D) — Skv/Sq already padded by ops.py.

    ``q_offset``: absolute position of q[0] on the kv timeline.
    ``kv_start`` (B,) int32: per-row left-pad count — kv positions before it
    are masked out (ragged-batch prefill).  None = no padding.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0 and sq % block_q == 0 and skv % block_k == 0
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kv_blocks = skv // block_k
    if kv_start is None:
        kv_start = jnp.zeros((b,), jnp.int32)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        kv_blocks=kv_blocks, kv_valid=skv)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // block_q, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, i, j: (b_, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g_=g: (b_, h // g_, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g_=g: (b_, h // g_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(kv_start.reshape(b, 1).astype(jnp.int32), q, k, v)
