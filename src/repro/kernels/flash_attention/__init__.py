from .ops import attention
from .ref import attention_ref
