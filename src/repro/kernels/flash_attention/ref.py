"""Pure-jnp oracle for (GQA / causal / windowed) attention.

Shapes:  q (B, Sq, Hq, D);  k, v (B, Skv, Hkv, D);  Hq % Hkv == 0.
``q_offset``: absolute position of q[0] within the kv timeline (Sq == Skv and
offset 0 for self-attention training; offset = kv_len - Sq for chunked
prefill / decode continuation).  ``window``: sliding-window size (0 = full).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0, scale: float | None = None,
                  kv_len=None, kv_start=None):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale

    rows = jnp.arange(sq)[:, None] + q_offset           # absolute q position
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    if kv_len is not None or kv_start is not None:
        mask = mask[None]                               # (B?,Sq,Skv)
        if kv_len is not None:                          # (B,) valid cache len
            mask = mask & (cols[None] < kv_len[:, None, None])
        if kv_start is not None:                        # (B,) left-pad count
            mask = mask & (cols[None] >= kv_start[:, None, None])
        mask = mask[:, None]                            # (B,1,Sq,Skv)
    else:
        mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    # fully-masked rows (e.g. pad queries whose whole causal range is pad)
    # output 0, matching the flash kernel's l==0 convention — never NaN
    p = p * jnp.any(mask, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0, scale: float | None = None,
                  kv_len=None, kv_start=None, block_q: int = 512):
    """Query-chunked attention in pure XLA — the production fallback path.

    Same math as the oracle, but scores are materialized one q-block at a
    time (scan + checkpoint), so peak memory is O(bq·Skv·H) instead of
    O(Sq·Skv·H); the backward pass recomputes per-block scores.  This is
    what the dry-run lowers (the Pallas kernel is the TPU-runtime path, and
    ``interpret=True`` cannot be SPMD-partitioned).
    GQA heads stay grouped (no kv repeat materialization).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    bq = min(block_q, sq)
    pad = (-sq) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (sq + pad) // bq
    qc = q.reshape(b, nq, bq, hkv, g, d)
    qc = jnp.moveaxis(qc, 1, 0)                      # (nq, b, bq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    cols = jnp.arange(skv)[None, :]

    def chunk(_, xs):
        qb, i = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32) * scale,
                       kf)
        rows = i * bq + jnp.arange(bq)[:, None] + q_offset
        mask = jnp.ones((bq, skv), bool)
        if causal:
            mask &= cols <= rows
        if window:
            mask &= cols > rows - window
        mask = mask[None, None, None]                   # (1,1,1,bq,Skv)
        if kv_len is not None:                          # (B,) valid cache len
            mask = mask & (cols < kv_len[:, None]
                           )[:, None, None, None, :]
        if kv_start is not None:                        # (B,) left-pad count
            mask = mask & (cols >= kv_start[:, None]
                           )[:, None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        p = p * jnp.any(mask, axis=-1, keepdims=True)   # all-masked row -> 0
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
        return None, o.astype(q.dtype)

    _, oc = jax.lax.scan(jax.checkpoint(chunk), None,
                         (qc, jnp.arange(nq, dtype=jnp.int32)))
    out = jnp.moveaxis(oc, 0, 1).reshape(b, sq + pad, hq, d)
    return out[:, :sq]
