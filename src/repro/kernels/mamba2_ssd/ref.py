"""Pure-jnp oracle for the Mamba2 SSD (state-space duality) scan.

Shapes (G groups share B/C across H heads, H % G == 0):
  x  (B, S, H, P)    head channels
  dt (B, S, H)       softplus-ed timestep > 0
  A  (H,)            negative per-head decay rate
  Bm (B, S, G, N)    input projection onto state
  Cm (B, S, G, N)    state readout
  h0 (B, H, P, N)    initial state (or None)
Returns y (B, S, H, P), h_final (B, H, P, N).

`ssd_scan_ref` is the exact sequential recurrence (the oracle).
`ssd_chunked` is the parallel chunked form (same math, O(S L) not O(S^2));
it is the XLA production path and mirrors the Pallas kernel blocking.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(Bm, h):
    g = Bm.shape[2]
    return jnp.repeat(Bm, h // g, axis=2)


def ssd_scan_ref(x, dt, A, Bm, Cm, h0=None):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Bh = _expand_groups(Bm, h).astype(jnp.float32)   # (B,S,H,N)
    Ch = _expand_groups(Cm, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32))         # (B,S,H) in (0,1)

    def step(hprev, t):
        xt, at, Bt, Ct, dtt = t
        # h <- a h + (dt x) B^T   (outer product over (P, N))
        hnew = (at[..., None, None] * hprev
                + (dtt[..., None] * xt)[..., None] * Bt[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", hnew, Ct)
        return hnew, y

    hinit = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0),
          jnp.moveaxis(dtf, 1, 0))
    hlast, ys = jax.lax.scan(step, hinit, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, hlast.astype(jnp.float32)


def ssd_chunked(x, dt, A, Bm, Cm, h0=None, *, chunk: int = 64):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, L = s // chunk, chunk
    Bh = _expand_groups(Bm, h).astype(jnp.float32)
    Ch = _expand_groups(Cm, h).astype(jnp.float32)
    xf = x.astype(jnp.float32).reshape(b, nc, L, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, L, h)
    Bc = Bh.reshape(b, nc, L, h, n)
    Cc = Ch.reshape(b, nc, L, h, n)

    la = jnp.cumsum(dtf * A.astype(jnp.float32), axis=2)  # (B,nc,L,H) <= 0
    xb = xf * dtf[..., None]                               # dt-scaled input

    # ---- intra-chunk (attention-like, causal).  Mask BEFORE exp: for s > t
    # the segment sum is positive (exp overflows to inf) and inf*0 in the
    # backward pass would poison grads.
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]      # (B,nc,L,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    Lmat = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc) * Lmat  # (B,nc,L,L,H)
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, xb)

    # ---- per-chunk input state contribution
    wS = jnp.exp(la[:, :, -1:, :] - la)                    # (B,nc,L,H)
    chunk_state = jnp.einsum("bclhp,bclhn->bchpn", xb * wS[..., None], Bc)
    chunk_decay = jnp.exp(la[:, :, -1])                    # (B,nc,H)

    # ---- inter-chunk recurrence over chunk states
    def step(hprev, t):
        cs, cd = t
        hnew = cd[..., None, None] * hprev + cs
        return hnew, hprev                                  # emit state *before*

    hinit = (jnp.zeros((b, h, p, n), jnp.float32) if h0 is None
             else h0.astype(jnp.float32))
    hlast, hprevs = jax.lax.scan(
        step, hinit, (jnp.moveaxis(chunk_state, 1, 0),
                      jnp.moveaxis(chunk_decay, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                    # (B,nc,H,P,N)

    # ---- inter-chunk output: readout of the carried-in state
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Cc, hprevs) * jnp.exp(
        la)[..., None]

    y = (y_intra + y_inter).reshape(b, s, h, p).astype(x.dtype)
    return y, hlast.astype(jnp.float32)


def ssd_decode_ref(xt, dtt, A, Bt, Ct, hprev):
    """Single-token state update.  xt (B,H,P); dtt (B,H); Bt/Ct (B,G,N);
    hprev (B,H,P,N) -> (y (B,H,P), hnew)."""
    h = xt.shape[1]
    g = Bt.shape[1]
    Bh = jnp.repeat(Bt, h // g, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Ct, h // g, axis=1).astype(jnp.float32)
    a = jnp.exp(dtt.astype(jnp.float32) * A.astype(jnp.float32))
    hnew = (a[..., None, None] * hprev.astype(jnp.float32)
            + (dtt[..., None] * xt.astype(jnp.float32))[..., None]
            * Bh[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", hnew, Ch)
    return y.astype(xt.dtype), hnew.astype(jnp.float32)
