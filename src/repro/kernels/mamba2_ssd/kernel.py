"""Pallas TPU kernel for the chunked Mamba2 SSD scan.

One grid cell = one (batch, head, chunk).  The chunk dim is minor-most, so it
runs sequentially on TPU and the (P, N) state is carried in VMEM scratch
across chunks — the inter-chunk recurrence costs nothing extra in HBM
traffic.  Within a chunk everything is (L, ·) matmuls on the MXU:

  la     = cumsum(dt * A)                     (L,)      decay log-weights
  scores = (C B^T) ⊙ exp(la_t - la_s) causal  (L, L)
  y      = scores @ (dt·x)  +  exp(la) ⊙ (C @ state^T)
  state  = exp(la_L) state + ((dt·x) ⊙ exp(la_L - la))^T @ B

VMEM per cell at L=128, P=64, N=128: 4 tiles of (L,L)+(L,P)+(L,N)+(P,N)
fp32 ≈ 0.3 MiB — far under budget; L is the tuning knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                y_ref, hout_ref, state_ref, *, nchunks, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)              # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (L,)
    A = a_ref[0].astype(jnp.float32)                    # ()
    Bm = b_ref[0, :, 0].astype(jnp.float32)             # (L, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)             # (L, N)

    la = jnp.cumsum(dt * A)                              # (L,) <= 0
    xb = x * dt[:, None]

    seg = la[:, None] - la[None, :]                      # (L, L)
    causal = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * jnp.exp(jnp.where(causal, seg, -jnp.inf))

    h_in = state_ref[...]                                # (P, N)
    y_intra = jax.lax.dot_general(scores, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = jax.lax.dot_general(Cm, h_in, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
        * jnp.exp(la)[:, None]                           # (L, P)
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    w_end = jnp.exp(la[-1] - la)                         # (L,)
    state_ref[...] = (jnp.exp(la[-1]) * h_in
                      + jax.lax.dot_general(
                          xb * w_end[:, None], Bm,
                          (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(ic == nchunks - 1)
    def _fin():
        hout_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x, dt, A, Bm, Cm, h0, *, chunk: int = 128,
                       interpret: bool = False):
    """x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,H,N) (groups pre-
    expanded); h0 (B,H,P,N) -> y (B,S,H,P), h_final (B,H,P,N)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, nchunks=nc, chunk=chunk)
    y, hout = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c: (b_, c, h_)),
            pl.BlockSpec((1,), lambda b_, h_, c: (h_,)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, h0)
    return y, hout
