from .ops import ssd, ssd_decode
from .ref import ssd_chunked, ssd_decode_ref, ssd_scan_ref
