"""Public SSD op: group expansion, padding, impl dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import ssd_chunked_pallas
from .ref import ssd_chunked, ssd_decode_ref, ssd_scan_ref


def ssd(x, dt, A, Bm, Cm, h0=None, *, chunk: int = 64, impl: str = "chunked"):
    """Mamba2 SSD scan.  x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N);
    h0 (B,H,P,N) or None -> (y (B,S,H,P), h_final).

    impl: "scan" (exact sequential oracle) | "chunked" (parallel XLA path) |
    "pallas" | "pallas_interpret".
    """
    b, s, h, p = x.shape
    if impl == "scan":
        return ssd_scan_ref(x, dt, A, Bm, Cm, h0)

    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # dt=0 padding => a=1, xb=0: state passes through unchanged, y junk-but-
    # sliced-off, final state exact.

    if impl == "chunked":
        y, hl = ssd_chunked(x, dt, A, Bm, Cm, h0, chunk=chunk)
        return y[:, :s], hl

    interpret = impl == "pallas_interpret"
    n = Bm.shape[-1]
    g = Bm.shape[2]
    Bh = jnp.repeat(Bm, h // g, axis=2)
    Ch = jnp.repeat(Cm, h // g, axis=2)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y, hl = ssd_chunked_pallas(x, dt, A, Bh, Ch, h0, chunk=chunk,
                               interpret=interpret)
    return y[:, :s], hl


ssd_decode = ssd_decode_ref
