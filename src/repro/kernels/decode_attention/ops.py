"""Public decode-attention ops: GQA grouping, padding, impl dispatch.

Two entry points:
- ``decode_attention``      — contiguous (B, Skv, Hkv, D) cache.
- ``decode_attention_paged``— block-pool cache (NB, BS, Hkv, D) addressed
  through a per-row int32 block table (B, T); the jnp path gathers the
  table into a contiguous view (bit-identical by construction), the
  Pallas path walks the table in SMEM via scalar prefetch.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_decode_bhgd, flash_decode_paged_bhgd
from .ref import decode_attention_ref


def decode_attention(q, k, v, kv_len, *, window: int = 0,
                     scale: float | None = None, kv_start=None,
                     impl: str = "ref", block_k: int = 256):
    """q (B,Hq,D); k,v (B,Skv,Hkv,D); kv_len (B,) -> (B,Hq,D).

    ``kv_start`` (B,) int32 masks cache slots below it — the left-pad
    prefix a ragged prefill left in the cache (None = no padding).
    """
    if impl in ("ref", "xla"):
        # the jnp decode path is already linear-memory (scores (B,Hq,Skv))
        return decode_attention_ref(q, k, v, kv_len, window=window,
                                    scale=scale, kv_start=kv_start)
    interpret = impl == "pallas_interpret"
    b, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    bk = min(block_k, max(128, skv))

    qg = q.reshape(b, hkv, g, d)
    kt = jnp.swapaxes(k, 1, 2)                       # (B,Hkv,Skv,D)
    vt = jnp.swapaxes(v, 1, 2)
    # ragged Skv is padded to a block multiple inside flash_decode_bhgd

    out = flash_decode_bhgd(qg, kt, vt, kv_len, kv_start, window=window,
                            scale=scale, block_k=bk, interpret=interpret)
    return out.reshape(b, hq, d)


def decode_attention_paged(q, k_pool, v_pool, table, kv_len, *,
                           window: int = 0, scale: float | None = None,
                           kv_start=None, impl: str = "ref"):
    """Paged decode attention.

    q (B,Hq,D); k_pool/v_pool (NB,BS,Hkv,D); table (B,T) int32 of pool
    block ids; kv_len (B,) -> (B,Hq,D).  Row b's logical column c is
    pool[table[b, c // BS], c % BS]; entries past the row's length should
    be 0 (the reserved trash block) so every gather stays in bounds.
    """
    b, hq, d = q.shape
    nb, bs, hkv, _ = k_pool.shape
    if impl in ("ref", "xla"):
        t = table.shape[1]
        # gather the table into the contiguous layout and defer to the ref:
        # table indexing is pure gather, so this IS the semantics the
        # Pallas path must reproduce bit-for-bit.
        kc = k_pool[table].reshape(b, t * bs, hkv, d)
        vc = v_pool[table].reshape(b, t * bs, hkv, d)
        return decode_attention_ref(q, kc, vc, kv_len, window=window,
                                    scale=scale, kv_start=kv_start)
    interpret = impl == "pallas_interpret"
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    kp = jnp.swapaxes(k_pool, 1, 2)                  # (NB,Hkv,BS,D)
    vp = jnp.swapaxes(v_pool, 1, 2)
    out = flash_decode_paged_bhgd(qg, kp, vp, table, kv_len, kv_start,
                                  window=window, scale=scale,
                                  interpret=interpret)
    return out.reshape(b, hq, d)
