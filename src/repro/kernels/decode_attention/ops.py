"""Public decode-attention op: GQA grouping, padding, impl dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_decode_bhgd
from .ref import decode_attention_ref


def decode_attention(q, k, v, kv_len, *, window: int = 0,
                     scale: float | None = None, kv_start=None,
                     impl: str = "ref", block_k: int = 256):
    """q (B,Hq,D); k,v (B,Skv,Hkv,D); kv_len (B,) -> (B,Hq,D).

    ``kv_start`` (B,) int32 masks cache slots below it — the left-pad
    prefix a ragged prefill left in the cache (None = no padding).
    """
    if impl in ("ref", "xla"):
        # the jnp decode path is already linear-memory (scores (B,Hq,Skv))
        return decode_attention_ref(q, k, v, kv_len, window=window,
                                    scale=scale, kv_start=kv_start)
    interpret = impl == "pallas_interpret"
    b, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    bk = min(block_k, max(128, skv))

    qg = q.reshape(b, hkv, g, d)
    kt = jnp.swapaxes(k, 1, 2)                       # (B,Hkv,Skv,D)
    vt = jnp.swapaxes(v, 1, 2)
    pad = (-skv) % bk
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))

    out = flash_decode_bhgd(qg, kt, vt, kv_len, kv_start, window=window,
                            scale=scale, block_k=bk, interpret=interpret)
    return out.reshape(b, hq, d)
