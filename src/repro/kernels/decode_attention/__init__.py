from .ops import decode_attention, decode_attention_paged
from .ref import decode_attention_ref
