"""Pure-jnp oracle for single-token (decode) attention over a KV cache.

q (B, Hq, D) — one new token per sequence.
k, v (B, Skv, Hkv, D) — the cache; entries at positions >= kv_len are junk.
kv_len (B,) int32 — valid cache length per sequence (the new token's k/v must
already be written at kv_len-1 by the caller).
kv_start (B,) int32 — first valid cache position per sequence; entries below
it are left-pad slots from a ragged prefill and are masked out.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def decode_attention_ref(q, k, v, kv_len, *, window: int = 0,
                         scale: float | None = None, kv_start=None):
    b, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    kr = jnp.repeat(k, g, axis=2)                       # (B,Skv,Hq,D)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale       # (B,Hq,Skv)

    cols = jnp.arange(skv)[None, :]                      # (1,Skv)
    mask = cols < kv_len[:, None]
    if kv_start is not None:                             # (B,) left-pad count
        mask &= cols >= kv_start[:, None]
    if window:
        mask &= cols >= jnp.maximum(0, kv_len[:, None] - window)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    # all-masked row -> 0 output (flash kernel l==0 convention), never NaN
    p = p * jnp.any(mask, axis=-1, keepdims=True)[:, None, :]
    out = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
