"""Pallas TPU flash-decode: one query token vs. a long KV cache.

Decode is memory-bound (roofline: stream the whole cache at ~2 bytes/FLOP),
so the kernel's job is to stream K/V through VMEM exactly once while all G
query heads of a kv group ride along — GQA turns the dot into a (G, bk)
matmul, amortizing the K/V read across the group (the TPU adaptation of
GPU flash-decode, where warps split the cache instead).

Layout: q (B, Hkv, G, D); k, v (B, Hkv, Skv, D); (kv_start, kv_len) as a
(B, 2) int32 bounds plane in SMEM — start masks left-pad cache slots from
ragged prefill, len bounds the live suffix.
Grid (B, Hkv, Skv/bk) — kv dim minor-most/sequential; running softmax state
in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale, window, block_k, kv_blocks):
    ik = pl.program_id(2)
    kv_start = len_ref[0, 0]                 # first valid slot (left pad end)
    kv_len = len_ref[0, 1]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    col0 = ik * block_k
    live = col0 < kv_len
    live &= col0 + block_k > kv_start        # tile fully inside the left pad
    if window:
        live &= col0 + block_k > kv_len - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G,bk)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (cols < kv_len) & (cols >= kv_start)
        if window:
            mask &= cols >= kv_len - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        # no valid col so far (m_new == NEG_INF, e.g. kv_start >= kv_len)
        # must contribute 0, not exp(NEG_INF - NEG_INF) = 1 per col —
        # keeps l at 0 so _fin zeroes the row, matching the ref path
        p = jnp.where((m_new > 0.5 * NEG_INF)[:, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ik == kv_blocks - 1)
    def _fin():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "block_k", "interpret"))
def flash_decode_bhgd(q, k, v, kv_len, kv_start=None, *, window=0, scale=None,
                      block_k=256, interpret=False):
    """q (B,Hkv,G,D); k,v (B,Hkv,Skv,D); kv_len/kv_start (B,) ->
    (B,Hkv,G,D).  kv_start masks left-pad cache slots (None = 0)."""
    b, hkv, g, d = q.shape
    _, _, skv, _ = k.shape
    # Ragged tail: pad K/V with zeros up to a block_k multiple instead of
    # asserting divisibility.  The kv_len column mask already excludes the
    # pad columns from the softmax; zero-padding (not garbage) keeps the
    # masked p·v products finite on hardware.
    tail = (-skv) % block_k
    if tail:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, tail), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tail), (0, 0)))
        skv += tail
    scale = scale if scale is not None else d ** -0.5
    kv_blocks = skv // block_k
    if kv_start is None:
        kv_start = jnp.zeros((b,), jnp.int32)
    bounds = jnp.stack([kv_start.astype(jnp.int32),
                        kv_len.astype(jnp.int32)], axis=1)    # (B, 2) SMEM

    kernel = functools.partial(_dec_kernel, scale=scale, window=window,
                               block_k=block_k, kv_blocks=kv_blocks)
    return pl.pallas_call(
        kernel,
        grid=(b, hkv, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 2), lambda b_, h, j: (b_, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(bounds, q, k, v)


def _dec_paged_kernel(table_ref, bounds_ref, q_ref, k_ref, v_ref, o_ref,
                      acc_ref, m_ref, l_ref, *, scale, window, block_size,
                      table_width):
    """Block-table flash-decode body: grid dim 2 walks the row's table.

    Identical running-softmax math to `_dec_kernel`; the only change is
    that tile j holds *logical* columns [j·bs, (j+1)·bs) gathered from
    physical pool block `table[b, j]` by the BlockSpec index_map — dead
    table entries point at block 0 and are masked out by kv_len anyway."""
    b_ = pl.program_id(0)
    ik = pl.program_id(2)
    kv_start = bounds_ref[b_, 0]
    kv_len = bounds_ref[b_, 1]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    col0 = ik * block_size
    live = col0 < kv_len
    live &= col0 + block_size > kv_start
    if window:
        live &= col0 + block_size > kv_len - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (cols < kv_len) & (cols >= kv_start)
        if window:
            mask &= cols >= kv_len - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where((m_new > 0.5 * NEG_INF)[:, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ik == table_width - 1)
    def _fin():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret"))
def flash_decode_paged_bhgd(q, k_pool, v_pool, table, kv_len, kv_start=None,
                            *, window=0, scale=None, interpret=False):
    """Paged flash-decode: q (B,Hkv,G,D); k_pool/v_pool (NB,Hkv,BS,D);
    table (B,T) int32 of pool block ids -> (B,Hkv,G,D).

    Row b's logical cache column c lives at pool[table[b, c // BS], :,
    c % BS].  The table rides in as a scalar-prefetch operand so the K/V
    BlockSpec index_maps can gather physical blocks per grid step; the
    tile size IS the block size, so masking is byte-for-byte the
    contiguous kernel's.  Unused table entries should be 0 (the reserved
    trash block) — they are masked by kv_len but must still be valid ids."""
    b, hkv, g, d = q.shape
    nb, _, bs, _ = k_pool.shape
    t = table.shape[1]
    scale = scale if scale is not None else d ** -0.5
    if kv_start is None:
        kv_start = jnp.zeros((b,), jnp.int32)
    bounds = jnp.stack([kv_start.astype(jnp.int32),
                        kv_len.astype(jnp.int32)], axis=1)    # (B, 2)

    kernel = functools.partial(_dec_paged_kernel, scale=scale, window=window,
                               block_size=bs, table_width=t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # table, bounds — SMEM, index_map-visible
        grid=(b, hkv, t),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h, j, table_ref, bounds_ref:
                         (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, table_ref, bounds_ref:
                         (table_ref[b_, j], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h, j, table_ref, bounds_ref:
                         (table_ref[b_, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h, j, table_ref, bounds_ref:
                               (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), bounds, q, k_pool, v_pool)
