"""Shared neural layers: norms, rotary embeddings (incl. M-RoPE), GLU MLPs.

Everything is functional: ``*_init`` builds (params, logical_specs) pairs —
the spec tree mirrors the param tree with tuples of logical axis names
consumed by ``repro.sharding.rules``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any
Specs = Any


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, shape, specs, dtype, scale: float | None = None):
    """Truncated-normal init with 1/sqrt(fan_in) scale; returns (p, spec)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    p = (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
         * scale).astype(dtype)
    return p, specs


def zeros_init(shape, specs, dtype):
    return jnp.zeros(shape, dtype), specs


def ones_init(shape, specs, dtype):
    return jnp.ones(shape, dtype), specs


# ----------------------------------------------------------------- norms ----

def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------- ragged batch ----
# Prompts are LEFT-padded into shape-bucketed batches (runtime/server
# pack_prompts): row i holds `lengths[i]` real tokens in its last slots.
# These two helpers are the single source of truth for what that layout
# means — every family derives its positions and masks from them, so a
# request's logits cannot depend on which batch it was packed into.

def pad_mask(lengths, s_len: int):
    """(B,) real-token counts -> (B, S) bool, True at real-token slots of a
    left-padded batch.  A zero length (filler row) is all-False."""
    cols = jnp.arange(s_len, dtype=jnp.int32)[None, :]
    return cols >= (s_len - lengths.astype(jnp.int32))[:, None]


def ragged_positions(lengths, batch: int, s_len: int):
    """Per-row token positions + left-pad counts for a left-padded batch.

    Returns ``(positions (B, S) int32, kv_start (B,) int32 | None)``:
    positions count from 0 at each row's first REAL token (pad slots clamp
    to 0 — they are masked out of attention anyway), so rotary phases are
    identical however much padding the batch added.  ``lengths=None`` means
    a dense batch: absolute positions, no mask.
    """
    if lengths is None:
        pos = jnp.broadcast_to(jnp.arange(s_len, dtype=jnp.int32),
                               (batch, s_len))
        return pos, None
    kv_start = (s_len - lengths.astype(jnp.int32)).astype(jnp.int32)
    pos = jnp.arange(s_len, dtype=jnp.int32)[None, :] - kv_start[:, None]
    return jnp.maximum(pos, 0), kv_start


# ------------------------------------------------------------------ RoPE ----

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)     # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections: tuple[int, ...]):
    """Multimodal RoPE (Qwen2-VL): positions (3, ..., S) for (t, h, w);
    frequency lanes are partitioned among the three position streams."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # (half,)
    # choose which position stream drives each frequency lane
    sel = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos = jnp.stack([positions[i] for i in range(3)], axis=0)   # (3, ..., S)
    pos_per_lane = jnp.take(pos, jnp.asarray(sel), axis=0)      # (half, ..., S)
    pos_per_lane = jnp.moveaxis(pos_per_lane, 0, -1)            # (..., S, half)
    ang = pos_per_lane.astype(jnp.float32) * freqs              # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP ----

def mlp_init(key, d_model: int, d_ff: int, act: str, dtype,
             stack: tuple[int, ...] = ()) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 3)
    pre = stack
    pre_spec = ("layers",) * len(stack)
    if act in ("swiglu", "geglu"):
        wi, wi_s = dense_init(ks[0], (*pre, d_model, d_ff),
                              (*pre_spec, "embed", "mlp"), dtype)
        wg, wg_s = dense_init(ks[1], (*pre, d_model, d_ff),
                              (*pre_spec, "embed", "mlp"), dtype)
        wo, wo_s = dense_init(ks[2], (*pre, d_ff, d_model),
                              (*pre_spec, "mlp", "embed"), dtype)
        return ({"wi": wi, "wg": wg, "wo": wo},
                {"wi": wi_s, "wg": wg_s, "wo": wo_s})
    wi, wi_s = dense_init(ks[0], (*pre, d_model, d_ff),
                          (*pre_spec, "embed", "mlp"), dtype)
    wo, wo_s = dense_init(ks[2], (*pre, d_ff, d_model),
                          (*pre_spec, "mlp", "embed"), dtype)
    return {"wi": wi, "wo": wo}, {"wi": wi_s, "wo": wo_s}


def mlp_apply(params, x, act: str):
    if act in ("swiglu", "geglu"):
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        g = jnp.einsum("...d,df->...f", x, params["wg"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = h * g
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["wi"]))
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ------------------------------------------------------------- embedding ----

def embed_init(key, vocab: int, d_model: int, dtype):
    p, s = dense_init(key, (vocab, d_model), ("vocab", "embed"), dtype,
                      scale=1.0)
    return p, s


def embed_apply(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed_apply(table_or_w, x, fp32: bool = True):
    """Logits projection; table is (vocab, d) (tied) or (d, vocab)."""
    w = table_or_w
    if w.shape[0] < w.shape[1]:      # (d, vocab)
        out = jnp.einsum("...d,dv->...v", x, w)
    else:                            # (vocab, d) tied table
        out = jnp.einsum("...d,vd->...v", x, w)
    return out.astype(jnp.float32) if fp32 else out
