"""Decoder-only LM stack (dense / MoE / VLM) with scan-over-layers.

Three lowered entry points from one parameter tree — the LM-side analogue of
Cppless's alternative entry points (one source, several compiled programs):

  forward  (train)    tokens/embeds -> logits (B, S, V)
  prefill             tokens/embeds -> last-token logits (B, V) + KV cache
  decode              one token + cache -> logits (B, V) + updated cache
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .attention import (attn_decode, attn_decode_paged, attn_full, attn_init,
                        attn_prefill_paged)
from .layers import (embed_apply, embed_init, mlp_apply, mlp_init,
                     ragged_positions, rms_norm)
from .moe import moe_apply, moe_init
from .stacking import scan_layers


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def lm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    L = cfg.n_layers
    p, s = {}, {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)

    lp, ls = {}, {}
    lp["ln1"] = jnp.zeros((L, cfg.d_model), dt)
    ls["ln1"] = ("layers", "embed")
    lp["ln2"] = jnp.zeros((L, cfg.d_model), dt)
    ls["ln2"] = ("layers", "embed")
    lp["attn"], ls["attn"] = attn_init(
        ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt,
        bias=cfg.qkv_bias, stack=(L,))
    if cfg.moe.n_experts:
        lp["moe"], ls["moe"] = moe_init(
            ks[2], cfg.d_model, cfg.d_ff, cfg.moe.n_experts, cfg.act, dt,
            stack=(L,))
    else:
        lp["mlp"], ls["mlp"] = mlp_init(
            ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt, stack=(L,))
    p["layers"], s["layers"] = lp, ls

    p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    s["final_norm"] = ("embed",)
    if not cfg.tie_embeddings:
        p["unembed"], s["unembed"] = embed_init(
            ks[3], cfg.vocab_size, cfg.d_model, dt)
    return p, s


def _embed_in(p, cfg, tokens, embeds):
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = embed_apply(p["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype))
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "act_batch", "act_seq", "act_embed")


def _logits(p, cfg, x):
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    table = p["embed"] if cfg.tie_embeddings else p["unembed"]
    out = jnp.einsum("...d,vd->...v", x, table)
    out = shard(out, "act_batch", "act_seq", "act_vocab") if out.ndim == 3 \
        else shard(out, "act_batch", "act_vocab")
    return out.astype(jnp.float32) if cfg.logits_fp32 else out


def _ffn(lp, cfg: ModelConfig, h, dropless: bool = False):
    """Dense MLP or MoE; returns (y, (aux, zloss, drop)).

    ``dropless`` (the prefill/decode entry points): expert capacity covers
    the worst case, so a token's routing never depends on what else is in
    the batch — capacity dropping is a training-throughput trade, and it
    would make serving batch-composition-DEPENDENT.
    """
    if cfg.moe.n_experts:
        y, m = moe_apply(
            lp["moe"], h, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
            capacity_factor=(0.0 if dropless else cfg.moe.capacity_factor),
            act=cfg.act,
            impl=("ep_a2a" if cfg.moe.impl == "ep" else "replicated"))
        return y, (m["moe_aux"], m["moe_zloss"], m["moe_drop"])
    y = mlp_apply(lp["mlp"], h, cfg.act)
    y = shard(y, "act_batch", "act_seq", "act_embed")
    return y, (jnp.float32(0), jnp.float32(0), jnp.float32(0))


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots_saveable" else None)
    return jax.checkpoint(fn, policy=policy)


def lm_forward(p, cfg: ModelConfig, tokens=None, embeds=None, pos3d=None,
               attn_impl: str = "ref", lengths=None):
    """Training forward: full logits (B, S, V) + moe metrics."""
    x = _embed_in(p, cfg, tokens, embeds)
    b, s_len = x.shape[:2]
    positions, kv_start = ragged_positions(lengths, b, s_len)

    def body(carry, lp):
        x, aux = carry
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        h = attn_full(lp["attn"], h, positions, causal=True,
                      window=cfg.window, rope_theta=cfg.rope_theta,
                      mrope_sections=cfg.mrope_sections, pos3d=pos3d,
                      impl=attn_impl, kv_start=kv_start)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        h, m = _ffn(lp, cfg, h)
        x = x + h
        return (x, tuple(a + mm for a, mm in zip(aux, m))), None

    zero = (jnp.float32(0),) * 3
    (x, aux), _ = scan_layers(_remat(cfg, body), (x, zero), p["layers"],
                              use_scan=cfg.scan_layers)
    metrics = {"moe_aux": aux[0] / cfg.n_layers,
               "moe_zloss": aux[1] / cfg.n_layers,
               "moe_drop": aux[2] / cfg.n_layers}
    return _logits(p, cfg, x), metrics


def lm_prefill(p, cfg: ModelConfig, tokens=None, embeds=None, pos3d=None,
               attn_impl: str = "ref", lengths=None):
    """Prefill: last-token logits + populated KV cache.

    ``lengths`` (B,) int32: real-token count per left-padded row.  Pad keys
    are masked out of every attention layer and RoPE positions count real
    tokens, so a prompt's logits (and its cache suffix) are identical
    whatever ragged company it was packed with.  The cache records each
    row's first valid slot under ``"start"`` for the decode path.
    """
    x = _embed_in(p, cfg, tokens, embeds)
    b, s_len = x.shape[:2]
    positions, kv_start = ragged_positions(lengths, b, s_len)
    cdt = jnp.dtype(cfg.param_dtype)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        h, (k, v) = attn_full(lp["attn"], h, positions, causal=True,
                              window=cfg.window, rope_theta=cfg.rope_theta,
                              mrope_sections=cfg.mrope_sections, pos3d=pos3d,
                              impl=attn_impl, kv_start=kv_start,
                              return_kv=True)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        h, _ = _ffn(lp, cfg, h, dropless=True)
        if cfg.kv_quant == "int8":
            from .attention import quantize_kv
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            return x + h, (kq, vq, ks, vs)
        return x + h, (k.astype(cdt), v.astype(cdt))

    x, caches = scan_layers(body, x, p["layers"], use_scan=cfg.scan_layers)
    logits = _logits(p, cfg, x[:, -1])
    start = (jnp.zeros((b,), jnp.int32) if kv_start is None else kv_start)
    if cfg.kv_quant == "int8":
        ck, cv, cks, cvs = caches
        cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                 "idx": jnp.int32(s_len), "start": start}
    else:
        ck, cv = caches
        cache = {"k": ck, "v": cv, "idx": jnp.int32(s_len), "start": start}
    return logits, cache


def lm_init_cache(cfg: ModelConfig, batch: int, cap: int,
                  filled: int | None = None, start=None):
    """Abstract/zero cache of capacity ``cap``; idx = filled (default cap-1,
    i.e. the decode_32k cell: a full cache, new token in the last slot).
    ``start`` (B,) int32: per-row first valid slot (left-pad count from a
    ragged prefill); default 0 = fully dense rows."""
    cdt = jnp.dtype(cfg.param_dtype)
    shp = (cfg.n_layers, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    idx = cap - 1 if filled is None else filled
    if start is None:
        start = jnp.zeros((batch,), jnp.int32)
    if cfg.kv_quant == "int8":
        return {"k": jnp.zeros(shp, jnp.int8), "v": jnp.zeros(shp, jnp.int8),
                "k_scale": jnp.zeros(shp[:-1], jnp.float32),
                "v_scale": jnp.zeros(shp[:-1], jnp.float32),
                "idx": jnp.int32(idx), "start": start}
    return {"k": jnp.zeros(shp, cdt), "v": jnp.zeros(shp, cdt),
            "idx": jnp.int32(idx), "start": start}


def lm_decode(p, cfg: ModelConfig, cache, tokens, pos3d=None,
              attn_impl: str = "ref"):
    """One decode step.  tokens (B, 1) -> logits (B, V), updated cache."""
    x = _embed_in(p, cfg, tokens, None)
    idx = cache["idx"]
    start = cache.get("start")               # (B,) left-pad counts, or None
    if cfg.mrope_sections and pos3d is None:
        b = tokens.shape[0]
        rel = (jnp.full((b,), idx, jnp.int32) if start is None
               else idx - start.astype(jnp.int32))
        pos3d = jnp.broadcast_to(rel[None, :, None], (3, b, 1))

    quant = cfg.kv_quant == "int8"

    def body(x, xs):
        if quant:
            lp, ck, cv, cks, cvs = xs
        else:
            lp, ck, cv = xs
            cks = cvs = None
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        out = attn_decode(lp["attn"], h, ck, cv, idx,
                          window=cfg.window, rope_theta=cfg.rope_theta,
                          mrope_sections=cfg.mrope_sections,
                          pos3d=pos3d, impl=attn_impl,
                          cache_ks=cks, cache_vs=cvs, kv_start=start)
        h, ck, cv = out[:3]
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        h, _ = _ffn(lp, cfg, h, dropless=True)
        if quant:
            return x + h, (ck, cv, out[3], out[4])
        return x + h, (ck, cv)

    carry = {} if start is None else {"start": start}
    if quant:
        xs = (p["layers"], cache["k"], cache["v"], cache["k_scale"],
              cache["v_scale"])
        x, (ck, cv, cks, cvs) = scan_layers(body, x, xs,
                                            use_scan=cfg.scan_layers)
        logits = _logits(p, cfg, x[:, -1])
        return logits, {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                        "idx": idx + 1, **carry}
    x, (ck, cv) = scan_layers(body, x,
                              (p["layers"], cache["k"], cache["v"]),
                              use_scan=cfg.scan_layers)
    logits = _logits(p, cfg, x[:, -1])
    return logits, {"k": ck, "v": cv, "idx": idx + 1, **carry}


# -------------------------------------------------- paged (block-table) ----

def lm_decode_paged(p, cfg: ModelConfig, pool_k, pool_v, table, lens, live,
                    tokens, attn_impl: str = "ref"):
    """One decode step against a shared block pool.

    tokens (B,1); pool_k/pool_v (L,NB,BS,Hkv,D); table (B,T) int32;
    lens (B,) resident tokens per row; live (B,) bool.  Each row's new K/V
    lands at logical column ``lens[b]`` through its table (dead rows write
    the trash block).  Returns (logits (B,V), pool_k, pool_v) — per-row
    lens/table bookkeeping is the host's job (block refcounts live there).
    """
    x = _embed_in(p, cfg, tokens, None)

    def body(x, xs):
        lp, pk, pv = xs
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        h, pk, pv = attn_decode_paged(lp["attn"], h, pk, pv, table, lens,
                                      live, window=cfg.window,
                                      rope_theta=cfg.rope_theta,
                                      impl=attn_impl)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        h, _ = _ffn(lp, cfg, h, dropless=True)
        return x + h, (pk, pv)

    x, (pk, pv) = scan_layers(body, x, (p["layers"], pool_k, pool_v),
                              use_scan=cfg.scan_layers)
    logits = _logits(p, cfg, x[:, -1])
    return logits, pk, pv


def lm_prefill_paged_chunk(p, cfg: ModelConfig, tokens, pool_k, pool_v,
                           table, m, n_real, attn_impl: str = "ref"):
    """One chunk of continued prefill for a single row (B == 1).

    tokens (1,C) right-padded, n_real real; ``m`` tokens of the row are
    already resident in the pool, so this chunk covers logical columns
    [m, m + n_real).  Returns (last-real-token logits (1,V), pools).
    Chaining chunks with growing m reproduces a monolithic prefill's
    logits and cache bit-for-bit — that is the chunked-prefill contract
    the invariance matrix pins.
    """
    x = _embed_in(p, cfg, tokens, None)

    def body(x, xs):
        lp, pk, pv = xs
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        h, pk, pv = attn_prefill_paged(lp["attn"], h, pk, pv, table, m,
                                       n_real, window=cfg.window,
                                       rope_theta=cfg.rope_theta,
                                       impl=attn_impl)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        h, _ = _ffn(lp, cfg, h, dropless=True)
        return x + h, (pk, pv)

    x, (pk, pv) = scan_layers(body, x, (p["layers"], pool_k, pool_v),
                              use_scan=cfg.scan_layers)
    last = jax.lax.dynamic_slice(x, (0, n_real - 1, 0), (1, 1, x.shape[-1]))
    logits = _logits(p, cfg, last[:, 0])
    return logits, pk, pv
