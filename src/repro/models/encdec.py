"""Whisper-style encoder–decoder backbone.

The conv/mel frontend is a stub per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, d).  Sinusoidal positions stand in
for whisper's learned decoder positions (noted in DESIGN.md) so the decoder
honors arbitrary stress lengths.  Pre-LN layers with biased QKV, GELU MLP,
tied unembedding.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.decode_attention import decode_attention
from ..sharding import shard
from .attention import attn_decode, attn_full, attn_init
from .layers import embed_apply, embed_init, layer_norm, mlp_apply, mlp_init
from .stacking import scan_layers


def _sinusoid(seq_len: int, d: int, dtype, offset: int | jnp.ndarray = 0):
    pos = jnp.arange(seq_len) + offset                        # (S,)
    inv = 1.0 / (10000.0 ** (np.arange(0, d, 2) / d))
    ang = pos[:, None].astype(jnp.float32) * jnp.asarray(inv, jnp.float32)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _ln_init(L, d, dt):
    return ({"w": jnp.ones((L, d) if L else (d,), dt),
             "b": jnp.zeros((L, d) if L else (d,), dt)},
            {"w": (("layers", "embed") if L else ("embed",)),
             "b": (("layers", "embed") if L else ("embed",))})


def encdec_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.param_dtype)
    Le, Ld, d = cfg.encoder_layers, cfg.decoder_layers, cfg.d_model
    p, s = {}, {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, d, dt)

    ep, es = {}, {}
    ep["ln1"], es["ln1"] = _ln_init(Le, d, dt)
    ep["attn"], es["attn"] = attn_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim, dt, bias=True,
                                       stack=(Le,))
    ep["ln2"], es["ln2"] = _ln_init(Le, d, dt)
    ep["mlp"], es["mlp"] = mlp_init(ks[2], d, cfg.d_ff, "gelu", dt,
                                    stack=(Le,))
    p["encoder"], s["encoder"] = ep, es
    p["enc_norm"], s["enc_norm"] = _ln_init(0, d, dt)

    dp, ds = {}, {}
    dp["ln1"], ds["ln1"] = _ln_init(Ld, d, dt)
    dp["attn"], ds["attn"] = attn_init(ks[3], d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim, dt, bias=True,
                                       stack=(Ld,))
    dp["ln_x"], ds["ln_x"] = _ln_init(Ld, d, dt)
    dp["cross"], ds["cross"] = attn_init(ks[4], d, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.head_dim, dt,
                                         bias=True, stack=(Ld,))
    dp["ln2"], ds["ln2"] = _ln_init(Ld, d, dt)
    dp["mlp"], ds["mlp"] = mlp_init(ks[5], d, cfg.d_ff, "gelu", dt,
                                    stack=(Ld,))
    p["decoder"], s["decoder"] = dp, ds
    p["dec_norm"], s["dec_norm"] = _ln_init(0, d, dt)
    return p, s


def _ln(x, lnp, eps):
    return layer_norm(x, lnp["w"], lnp["b"], eps)


def encode(p, cfg: ModelConfig, frames, attn_impl: str = "ref"):
    """frames (B, S_enc, d) precomputed embeddings -> (B, S_enc, d)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s_len, _ = frames.shape
    x = frames.astype(dt) + _sinusoid(s_len, cfg.d_model, dt)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s_len, dtype=jnp.int32),
                                 (b, s_len))

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.rms_eps)
        h = attn_full(lp["attn"], h, positions, causal=False, rope_theta=0.0,
                      impl=attn_impl)
        x = x + h
        h = _ln(x, lp["ln2"], cfg.rms_eps)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return shard(x, "act_batch", "act_seq", "act_embed"), None

    x, _ = scan_layers(body, x, p["encoder"], use_scan=cfg.scan_layers)
    return _ln(x, p["enc_norm"], cfg.rms_eps)


def decode_train(p, cfg: ModelConfig, tokens, enc_out,
                 attn_impl: str = "ref", collect_cache: bool = False,
                 last_only: bool = False):
    """Teacher-forcing decoder.  Returns logits (+ caches when prefilling)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s_len = tokens.shape
    x = embed_apply(p["embed"], tokens).astype(dt)
    x = x + _sinusoid(s_len, cfg.d_model, dt)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s_len, dtype=jnp.int32),
                                 (b, s_len))
    cdt = jnp.dtype(cfg.param_dtype)

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.rms_eps)
        h, (sk, sv) = attn_full(lp["attn"], h, positions, causal=True,
                                rope_theta=0.0, impl=attn_impl,
                                return_kv=True)
        x = x + h
        h = _ln(x, lp["ln_x"], cfg.rms_eps)
        h, (xk, xv) = attn_full(lp["cross"], h, positions, kv_x=enc_out,
                                impl=attn_impl, return_kv=True)
        x = x + h
        h = _ln(x, lp["ln2"], cfg.rms_eps)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        x = shard(x, "act_batch", "act_seq", "act_embed")
        ys = ((sk.astype(cdt), sv.astype(cdt)),
              (xk.astype(cdt), xv.astype(cdt))) if collect_cache else 0
        return x, ys

    x, caches = scan_layers(body, x, p["decoder"],
                            use_scan=cfg.scan_layers)
    if last_only:
        x = x[:, -1:]
    x = _ln(x, p["dec_norm"], cfg.rms_eps)
    logits = jnp.einsum("...d,vd->...v", x, p["embed"])
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    logits = logits.astype(jnp.float32) if cfg.logits_fp32 else logits
    if collect_cache:
        (sk, sv), (xk, xv) = caches
        cache = {"k": sk, "v": sv, "cross_k": xk, "cross_v": xv,
                 "idx": jnp.int32(s_len)}
        return logits, cache
    return logits, {}


def encdec_init_cache(cfg: ModelConfig, batch: int, cap: int,
                      enc_len: int = 1500, filled: int | None = None):
    cdt = jnp.dtype(cfg.param_dtype)
    Ld = cfg.decoder_layers
    shp = (Ld, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    xshp = (Ld, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    idx = cap - 1 if filled is None else filled
    return {"k": jnp.zeros(shp, cdt), "v": jnp.zeros(shp, cdt),
            "cross_k": jnp.zeros(xshp, cdt), "cross_v": jnp.zeros(xshp, cdt),
            "idx": jnp.int32(idx)}


def encdec_decode(p, cfg: ModelConfig, cache, tokens,
                  attn_impl: str = "ref"):
    """One decoder step against self + cross caches."""
    dt = jnp.dtype(cfg.compute_dtype)
    b = tokens.shape[0]
    idx = cache["idx"]
    x = embed_apply(p["embed"], tokens).astype(dt)
    x = x + _sinusoid(1, cfg.d_model, dt, offset=idx)
    enc_len = cache["cross_k"].shape[2]

    def body(x, xs):
        lp, sk, sv, xk, xv = xs
        h = _ln(x, lp["ln1"], cfg.rms_eps)
        h, sk, sv = attn_decode(lp["attn"], h, sk, sv, idx, rope_theta=0.0,
                                impl=attn_impl)
        x = x + h
        h = _ln(x, lp["ln_x"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
        q = q + lp["cross"]["bq"]
        kv_len = jnp.full((b,), enc_len, jnp.int32)
        o = decode_attention(q[:, 0], xk, xv, kv_len, impl=attn_impl)
        x = x + jnp.einsum("bhk,hkd->bd", o, lp["cross"]["wo"])[:, None]
        h = _ln(x, lp["ln2"], cfg.rms_eps)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, (sk, sv)

    x, (sk, sv) = scan_layers(
        body, x, (p["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]),
        use_scan=cfg.scan_layers)
    x = _ln(x[:, -1], p["dec_norm"], cfg.rms_eps)
    logits = jnp.einsum("...d,vd->...v", x, p["embed"])
    logits = logits.astype(jnp.float32) if cfg.logits_fp32 else logits
    return logits, {**cache, "k": sk, "v": sv, "idx": idx + 1}
