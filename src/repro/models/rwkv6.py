"""RWKV-6 (Finch) block: data-dependent-decay time-mix + channel-mix.

Time-mix uses the ddlerp token-shift (5-way LoRA-modulated interpolation
with the previous token), a LoRA-projected per-channel decay
w = exp(-exp(w0 + lora(x))), and the WKV recurrence from kernels/rwkv6_wkv.
The model passes log-w = -exp(...) straight to the kernel — w itself is
never materialized, which keeps the exp() composition stable in bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.rwkv6_wkv import wkv6, wkv6_decode
from ..sharding import shard
from .layers import dense_init

MIX_LORA = 32
DECAY_LORA = 64


def rwkv6_init(key, d_model: int, d_ff: int, *, n_heads: int, head_dim: int,
               dtype, stack: tuple[int, ...] = ()):
    att = n_heads * head_dim
    ks = jax.random.split(key, 16)
    pre, ps = stack, ("layers",) * len(stack)
    p, s = {}, {}

    # ---- time-mix
    for i, nm in enumerate(("wr", "wk", "wv", "wg")):
        p[nm], s[nm] = dense_init(ks[i], (*pre, d_model, att),
                                  (*ps, "embed", "inner"), dtype)
    p["wo"], s["wo"] = dense_init(ks[4], (*pre, att, d_model),
                                  (*ps, "inner", "embed"), dtype)
    p["mu_x"] = jnp.full((*pre, d_model), 0.5, dtype)
    s["mu_x"] = (*ps, "embed")
    p["mu_rkvwg"] = jnp.full((*pre, 5, d_model), 0.5, dtype)
    s["mu_rkvwg"] = (*ps, None, "embed")
    p["mix_a"], s["mix_a"] = dense_init(
        ks[5], (*pre, d_model, 5 * MIX_LORA), (*ps, "embed", None), dtype)
    p["mix_b"], s["mix_b"] = dense_init(
        ks[6], (*pre, 5, MIX_LORA, d_model), (*ps, None, "lora", "embed"),
        dtype)
    p["w0"] = jnp.zeros((*pre, att), dtype) - 0.5   # exp(-exp(-0.5)) ≈ .55
    s["w0"] = (*ps, "inner")
    p["decay_a"], s["decay_a"] = dense_init(
        ks[7], (*pre, d_model, DECAY_LORA), (*ps, "embed", None), dtype)
    p["decay_b"], s["decay_b"] = dense_init(
        ks[8], (*pre, DECAY_LORA, att), (*ps, "lora", "inner"), dtype)
    p["u"] = jnp.zeros((*pre, att), dtype)
    s["u"] = (*ps, "inner")
    p["ln_x_w"] = jnp.ones((*pre, att), dtype)
    s["ln_x_w"] = (*ps, "inner")
    p["ln_x_b"] = jnp.zeros((*pre, att), dtype)
    s["ln_x_b"] = (*ps, "inner")

    # ---- channel-mix
    p["cm_mu_k"] = jnp.full((*pre, d_model), 0.5, dtype)
    s["cm_mu_k"] = (*ps, "embed")
    p["cm_mu_r"] = jnp.full((*pre, d_model), 0.5, dtype)
    s["cm_mu_r"] = (*ps, "embed")
    p["cm_wk"], s["cm_wk"] = dense_init(ks[9], (*pre, d_model, d_ff),
                                        (*ps, "embed", "mlp"), dtype)
    p["cm_wv"], s["cm_wv"] = dense_init(ks[10], (*pre, d_ff, d_model),
                                        (*ps, "mlp", "embed"), dtype)
    p["cm_wr"], s["cm_wr"] = dense_init(ks[11], (*pre, d_model, d_model),
                                        (*ps, "embed", None), dtype)
    return p, s


def _group_norm(x, w, b, n_heads: int, eps: float = 1e-5):
    """Per-head layernorm over the head channel dim.  x (..., H, V)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    shape = x.shape[:-2] + (n_heads * x.shape[-1],)
    y = y.reshape(shape) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return y.astype(x.dtype)


def _ddlerp(p, x, xprev):
    """5-way LoRA-modulated token-shift; returns (xr, xk, xv, xw, xg)."""
    dx = xprev - x
    xxx = x + dx * p["mu_x"]
    t = jnp.tanh(jnp.einsum("...d,dm->...m", xxx, p["mix_a"]))
    t = t.reshape(*t.shape[:-1], 5, MIX_LORA)
    offs = jnp.einsum("...fm,fmd->f...d", t, p["mix_b"])     # (5, ..., d)
    mus = jnp.moveaxis(p["mu_rkvwg"], -2, 0)                 # (5, d)
    mus = mus.reshape(5, *(1,) * (offs.ndim - 2), -1) + offs
    return tuple(x + dx * mus[i] for i in range(5))


def _tmix_projections(p, x, xprev, n_heads: int, head_dim: int):
    xr, xk, xv, xw, xg = _ddlerp(p, x, xprev)
    shp = x.shape[:-1] + (n_heads, head_dim)
    r = jnp.einsum("...d,da->...a", xr, p["wr"]).reshape(shp)
    k = jnp.einsum("...d,da->...a", xk, p["wk"]).reshape(shp)
    v = jnp.einsum("...d,da->...a", xv, p["wv"]).reshape(shp)
    g = jnp.einsum("...d,da->...a", xg, p["wg"])
    dec = jnp.einsum("...d,dl->...l", xw, p["decay_a"])
    dec = jnp.einsum("...l,la->...a", jnp.tanh(dec), p["decay_b"])
    logw = -jnp.exp((p["w0"] + dec).astype(jnp.float32)).reshape(shp)
    return r, k, v, g, logw


def rwkv6_time_mix(p, x, *, n_heads: int, head_dim: int, s0=None,
                   shift0=None, chunk: int = 64, impl: str = "chunked"):
    """x (B,S,d) -> (y, wkv_state, last_x)."""
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if shift0 is not None:
        xprev = xprev.at[:, 0].set(shift0)
    r, k, v, g, logw = _tmix_projections(p, x, xprev, n_heads, head_dim)
    r = shard(r, "act_batch", "act_seq", "act_inner", None)
    u = p["u"].astype(jnp.float32).reshape(n_heads, head_dim)
    o, s_last = wkv6(r, k, v, logw, u, s0, chunk=chunk, impl=impl)
    o = _group_norm(o, p["ln_x_w"], p["ln_x_b"], n_heads)
    o = o * jax.nn.silu(g)
    y = jnp.einsum("bsa,ad->bsd", o, p["wo"])
    return shard(y, "act_batch", "act_seq", "act_embed"), s_last, x[:, -1]


def rwkv6_time_mix_decode(p, x, s0, shift0, *, n_heads: int, head_dim: int):
    """x (B,1,d); shift0 (B,d); s0 (B,H,K,V)."""
    xprev = shift0[:, None]
    r, k, v, g, logw = _tmix_projections(p, x, xprev, n_heads, head_dim)
    u = p["u"].astype(jnp.float32).reshape(n_heads, head_dim)
    o, s_new = wkv6_decode(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u, s0)
    o = _group_norm(o[:, None], p["ln_x_w"], p["ln_x_b"], n_heads)
    o = o * jax.nn.silu(g)
    y = jnp.einsum("bsa,ad->bsd", o, p["wo"])
    return y, s_new, x[:, -1]


def rwkv6_channel_mix(p, x, shift0=None):
    """x (B,S,d) -> (y, last_x)."""
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if shift0 is not None:
        xprev = xprev.at[:, 0].set(shift0)
    dx = xprev - x
    xk = x + dx * p["cm_mu_k"]
    xr = x + dx * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["cm_wk"])))
    kk = shard(kk, "act_batch", "act_seq", "act_mlp")
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cm_wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_wr"]))
    return shard(r * kv, "act_batch", "act_seq", "act_embed"), x[:, -1]
