"""scan-vs-unroll over stacked layer params.

`lax.scan` keeps HLO size independent of depth (fast compiles, the smoke/
training default).  Unrolling (`use_scan=False`) is what the dry-run lowers:
XLA's HloCostAnalysis counts a while body ONCE (trip count unknown), so
scanned modules under-report FLOPs/bytes by ~L×; unrolling also lets the
scheduler overlap per-layer collectives — the production-perf choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_layers(body, carry, xs, *, use_scan: bool = True):
    """Like ``jax.lax.scan(body, carry, xs)`` with an unrolled variant."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree.map(lambda *e: jnp.stack(e), *ys)
    return carry, stacked
