"""GQA/MQA/MHA attention block with KV-cache, RoPE / M-RoPE, windows.

Three execution modes, all from the same params:
  * full  — training / prefill: flash kernel (TPU) or jnp oracle (CPU)
  * prefill — full + returns the populated KV cache
  * decode — one token against a cache (flash-decode kernel or oracle)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.decode_attention import decode_attention, decode_attention_paged
from ..kernels.flash_attention import attention
from ..sharding import shard
from .layers import apply_mrope, apply_rope, dense_init


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, dtype, *, bias: bool = False,
              stack: tuple[int, ...] = ()):
    ks = jax.random.split(key, 4)
    pre = stack
    ps = ("layers",) * len(stack)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (*pre, d_model, n_heads, head_dim),
                                  (*ps, "embed", "heads", "head_dim"), dtype)
    p["wk"], s["wk"] = dense_init(ks[1], (*pre, d_model, n_kv_heads, head_dim),
                                  (*ps, "embed", "kv_heads", "head_dim"), dtype)
    p["wv"], s["wv"] = dense_init(ks[2], (*pre, d_model, n_kv_heads, head_dim),
                                  (*ps, "embed", "kv_heads", "head_dim"), dtype)
    p["wo"], s["wo"] = dense_init(ks[3], (*pre, n_heads, head_dim, d_model),
                                  (*ps, "heads", "head_dim", "embed"), dtype)
    if bias:
        for nm, hs, ax in (("bq", n_heads, "heads"),
                           ("bk", n_kv_heads, "kv_heads"),
                           ("bv", n_kv_heads, "kv_heads")):
            p[nm] = jnp.zeros((*pre, hs, head_dim), dtype)
            s[nm] = (*ps, ax, "head_dim")
    return p, s


def _project(p, x, positions, *, rope_theta, mrope_sections, pos3d):
    """x (B,S,d) -> q (B,S,Hq,D), k/v (B,S,Hkv,D), rotary applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if rope_theta:
        if mrope_sections:
            q = apply_mrope(q, pos3d, rope_theta, mrope_sections)
            k = apply_mrope(k, pos3d, rope_theta, mrope_sections)
        else:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
    q = shard(q, "act_batch", "act_seq", "act_heads", None)
    k = shard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = shard(v, "act_batch", "act_seq", "act_kv_heads", None)
    return q, k, v


def attn_full(p, x, positions, *, causal=True, window=0, rope_theta=0.0,
              mrope_sections=(), pos3d=None, impl="ref", kv_x=None,
              kv_start=None, return_kv=False) -> Any:
    """Training / prefill attention.  kv_x: cross-attention source.
    kv_start (B,): per-row left-pad count — pad keys are masked out."""
    if kv_x is None:
        q, k, v = _project(p, x, positions, rope_theta=rope_theta,
                           mrope_sections=mrope_sections, pos3d=pos3d)
    else:  # cross-attn: q from x, k/v from encoder output (no rope)
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        causal = False
    o = attention(q, k, v, causal=causal, window=window, impl=impl,
                  kv_start=kv_start)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    out = shard(out, "act_batch", "act_seq", "act_embed")
    if return_kv:
        return out, (k, v)
    return out


def quantize_kv(x):
    """Per-(token, head) symmetric int8.  x (..., D) -> (q int8, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attn_decode(p, x, cache_k, cache_v, idx, *, window=0, rope_theta=0.0,
                mrope_sections=(), pos3d=None, impl="ref",
                update_cache=True, cache_ks=None, cache_vs=None,
                kv_start=None):
    """One-token attention.  x (B,1,d); cache_k/v (B,Smax,Hkv,D); idx scalar
    position of the new token.  With int8-quantized caches, cache_ks/vs are
    the (B,Smax,Hkv) scale planes (updated and returned alongside).
    kv_start (B,): per-row first valid cache slot — positions below it are
    left-pad junk from a ragged prefill; it also offsets RoPE so the new
    token's rotary position counts real tokens, not buffer slots.
    Returns (out, cache_k, cache_v[, cache_ks, cache_vs])."""
    b = x.shape[0]
    quant = cache_ks is not None
    positions = jnp.full((b, 1), idx, jnp.int32)
    if kv_start is not None:
        positions = positions - kv_start[:, None].astype(jnp.int32)
    q, k, v = _project(p, x, positions, rope_theta=rope_theta,
                       mrope_sections=mrope_sections, pos3d=pos3d)
    if update_cache:
        if quant:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            cache_k = jax.lax.dynamic_update_slice(cache_k, kq,
                                                   (0, idx, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(cache_v, vq,
                                                   (0, idx, 0, 0))
            cache_ks = jax.lax.dynamic_update_slice(cache_ks, ks,
                                                    (0, idx, 0))
            cache_vs = jax.lax.dynamic_update_slice(cache_vs, vs,
                                                    (0, idx, 0))
        else:
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k.astype(cache_k.dtype), (0, idx, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v.astype(cache_v.dtype), (0, idx, 0, 0))
    kv_len = jnp.full((b,), idx + 1, jnp.int32)
    if quant:
        kd = dequantize_kv(cache_k, cache_ks, q.dtype)
        vd = dequantize_kv(cache_v, cache_vs, q.dtype)
    else:
        kd, vd = cache_k, cache_v
    o = decode_attention(q[:, 0], kd, vd, kv_len, window=window, impl=impl,
                         kv_start=kv_start)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    if quant:
        return out, cache_k, cache_v, cache_ks, cache_vs
    return out, cache_k, cache_v


# -------------------------------------------------- paged (block-table) ----
# Paged rows are RIGHT-dense: row content occupies logical columns
# [0, len), kv_start is always 0, and RoPE position == logical column —
# the per-row block table maps logical columns to physical pool blocks.
# Both facts together are what make paged decode bit-identical to the
# left-padded solo path: the per-token q/k values are equal (same RoPE
# positions), and the masked-softmax reductions are placement/width
# invariant as long as every gathered width stays a power of two.

def attn_decode_paged(p, x, pool_k, pool_v, table, lens, live, *, window=0,
                      rope_theta=0.0, impl="ref"):
    """One-token attention against a block pool.

    x (B,1,d); pool_k/pool_v (NB,BS,Hkv,D); table (B,T) int32;
    lens (B,) tokens already resident per row; live (B,) bool.

    The new token is written at logical column ``lens`` — physically
    ``pool[table[b, lens // BS], lens % BS]``.  Dead rows write to the
    reserved trash block 0 (never read unmasked: their kv_len is 0, so
    the kernel's l == 0 guard zeroes the whole row).
    Returns (out, pool_k, pool_v)."""
    b = x.shape[0]
    bs = pool_k.shape[1]
    lens = lens.astype(jnp.int32)
    positions = lens[:, None]                       # right-dense: pos == len
    q, k, v = _project(p, x, positions, rope_theta=rope_theta,
                       mrope_sections=(), pos3d=None)
    rows = jnp.arange(b)
    blk = jnp.where(live, table[rows, lens // bs], 0)
    off = lens % bs
    pool_k = pool_k.at[blk, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[blk, off].set(v[:, 0].astype(pool_v.dtype))
    kv_len = jnp.where(live, lens + 1, 0).astype(jnp.int32)
    o = decode_attention_paged(q[:, 0], pool_k, pool_v, table, kv_len,
                               window=window, impl=impl)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return out, pool_k, pool_v


def attn_prefill_paged(p, x, pool_k, pool_v, table, m, n_real, *, window=0,
                       rope_theta=0.0, impl="ref"):
    """One chunk of continued prefill against a block pool (B == 1).

    x (1,C,d) — the chunk's embeddings, real tokens in [0, n_real), the
    rest right-pad; ``m`` is how many tokens of this row the pool already
    holds, so chunk token j is logical column m + j.  K/V for real chunk
    positions scatter into the row's table-mapped blocks (pad positions
    go to the trash block); attention runs q_offset = m against the full
    gathered table view, masked to kv_len = m + n_real.  Chaining calls
    with growing ``m`` reproduces a monolithic prefill bit-for-bit.
    Returns (out (1,C,d-model), pool_k, pool_v)."""
    _, c, _ = x.shape
    bs = pool_k.shape[1]
    t = table.shape[1]
    j = jnp.arange(c)
    positions = (m + j)[None, :]
    q, k, v = _project(p, x, positions, rope_theta=rope_theta,
                       mrope_sections=(), pos3d=None)
    real = j < n_real
    ti = jnp.where(real, (m + j) // bs, 0)          # clamp pad lookups
    blk = jnp.where(real, table[0, ti], 0)
    off = (m + j) % bs
    pool_k = pool_k.at[blk, off].set(k[0].astype(pool_k.dtype))
    pool_v = pool_v.at[blk, off].set(v[0].astype(pool_v.dtype))
    kview = pool_k[table[0]].reshape(1, t * bs, *pool_k.shape[2:])
    vview = pool_v[table[0]].reshape(1, t * bs, *pool_v.shape[2:])
    kv_len = jnp.full((1,), m + n_real, jnp.int32)
    o = attention(q, kview.astype(q.dtype), vview.astype(q.dtype),
                  causal=True, window=window, q_offset=m, kv_len=kv_len,
                  impl=impl)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, pool_k, pool_v
