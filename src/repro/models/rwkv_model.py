"""RWKV-6 full model: embed -> [time-mix + channel-mix] x L -> unembed.

Attention-free: the "cache" is O(1) in sequence length — per-layer WKV state
(B, H, K, V) plus two token-shift vectors (B, d).  This is why rwkv6 runs
the long_500k cell that full-attention archs skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import shard
from .layers import embed_apply, embed_init, layer_norm, pad_mask, rms_norm
from .rwkv6 import (rwkv6_channel_mix, rwkv6_init, rwkv6_time_mix,
                    rwkv6_time_mix_decode)
from .stacking import scan_layers


def rwkv_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    L = cfg.n_layers
    p, s = {}, {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)
    lp, ls = rwkv6_init(ks[1], cfg.d_model, cfg.d_ff,
                        n_heads=cfg.ssm.n_heads, head_dim=cfg.ssm.head_dim,
                        dtype=dt, stack=(L,))
    lp["ln1"] = jnp.zeros((L, cfg.d_model), dt)
    ls["ln1"] = ("layers", "embed")
    lp["ln2"] = jnp.zeros((L, cfg.d_model), dt)
    ls["ln2"] = ("layers", "embed")
    p["layers"], s["layers"] = lp, ls
    p["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    s["final_norm"] = ("embed",)
    p["unembed"], s["unembed"] = embed_init(ks[2], cfg.vocab_size,
                                            cfg.d_model, dt)
    return p, s


def _split(lp):
    tm = {k: v for k, v in lp.items()
          if not k.startswith("cm_") and k not in ("ln1", "ln2")}
    cm = {k: v for k, v in lp.items() if k.startswith("cm_")}
    return tm, cm


def rwkv_forward(p, cfg: ModelConfig, tokens, ssm_impl: str = "chunked",
                 collect_cache: bool = False, last_only: bool = False,
                 lengths=None):
    """``lengths`` (B,) int32: real-token count per left-padded row.  The
    per-layer mix inputs are zeroed on pad slots, so a pad step contributes
    nothing to the WKV state or the token-shift stream — the first real
    token sees exactly the zero shift/state a fresh decode would (pad steps
    are identity transitions), whatever the batch's padded length."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_apply(p["embed"], tokens).astype(dt)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    mask = (None if lengths is None
            else pad_mask(lengths, tokens.shape[1])[..., None])

    def body(x, lp):
        tm, cm = _split(lp)
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        if mask is not None:
            h = h * mask.astype(h.dtype)
        h, s_last, tshift = rwkv6_time_mix(
            tm, h, n_heads=cfg.ssm.n_heads, head_dim=cfg.ssm.head_dim,
            chunk=cfg.ssm.chunk, impl=ssm_impl)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        if mask is not None:
            h = h * mask.astype(h.dtype)
        h, cshift = rwkv6_channel_mix(cm, h)
        x = x + h
        ys = (s_last, tshift, cshift) if collect_cache else 0
        return x, ys

    x, caches = scan_layers(body, x, p["layers"],
                            use_scan=cfg.scan_layers)
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("...d,vd->...v", x, p["unembed"])
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    logits = logits.astype(jnp.float32) if cfg.logits_fp32 else logits
    if collect_cache:
        wkv, tshift, cshift = caches
        cache = {"wkv": wkv, "shift_att": tshift, "shift_ffn": cshift,
                 "idx": jnp.int32(tokens.shape[1])}
        return logits, cache
    return logits, {}


def rwkv_init_cache(cfg: ModelConfig, batch: int, cap: int,
                    filled: int | None = None):
    L, h, k = cfg.n_layers, cfg.ssm.n_heads, cfg.ssm.head_dim
    d = cfg.d_model
    cdt = jnp.dtype(cfg.compute_dtype)
    idx = cap - 1 if filled is None else filled
    return {"wkv": jnp.zeros((L, batch, h, k, k), jnp.float32),
            "shift_att": jnp.zeros((L, batch, d), cdt),
            "shift_ffn": jnp.zeros((L, batch, d), cdt),
            "idx": jnp.int32(idx)}


def rwkv_decode(p, cfg: ModelConfig, cache, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_apply(p["embed"], tokens).astype(dt)   # (B, 1, d)

    def body(x, xs):
        lp, wkv, sa, sf = xs
        tm, cm = _split(lp)
        h = rms_norm(x, lp["ln1"], cfg.rms_eps)
        sa_new = h[:, -1]
        h, wkv, _ = rwkv6_time_mix_decode(
            tm, h, wkv, sa, n_heads=cfg.ssm.n_heads,
            head_dim=cfg.ssm.head_dim)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.rms_eps)
        sf_new = h[:, -1]
        h, _ = rwkv6_channel_mix(cm, h, shift0=sf)
        x = x + h
        return x, (wkv, sa_new, sf_new)

    x, (wkv, sa, sf) = scan_layers(
        body, x, (p["layers"], cache["wkv"], cache["shift_att"],
                  cache["shift_ffn"]), use_scan=cfg.scan_layers)
    x = rms_norm(x[:, -1], p["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("...d,vd->...v", x, p["unembed"])
    logits = logits.astype(jnp.float32) if cfg.logits_fp32 else logits
    return logits, {"wkv": wkv, "shift_att": sa, "shift_ffn": sf,
                    "idx": cache["idx"] + 1}
