"""Mixture-of-Experts layer — the in-core mirror of the paper's dispatcher.

Token->expert dispatch is a fork-join scatter/gather, exactly the shape of
Cppless's task->worker dispatch: serialize (pack tokens into capacity
buffers), dispatch (to the expert-parallel `model` mesh axis), execute,
gather (combine weighted by router gates), with *drops* (capacity overflow)
playing the role of load imbalance.

Implementation: `shard_map` over the whole mesh.  Activations enter
replicated across the `model` axis (TP-style), so each model shard already
holds every local token; it packs buffers only for the experts it owns,
runs them, scatters back its partial output, and a psum over `model`
combines — the same collective the dense TP MLP uses, so MoE costs one
psum extra nothing.  Per-shard sort-based packing keeps everything static-
shaped (capacity C per expert) and jit/grad-safe.

On a (1, 1) mesh (CPU smoke tests) every collective degenerates to identity
and the code path is identical.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init

# jax moved shard_map to the top level and later renamed its replication-
# check kwarg (check_rep -> check_vma) in separate releases, so resolve the
# symbol and the kwarg independently: location by hasattr, kwarg by
# signature (jax 0.5-0.6 has top-level shard_map but still check_rep).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised on jax 0.4.x installs
    from jax.experimental.shard_map import shard_map as _shard_map
try:
    _SHARD_MAP_KW = (
        {"check_vma": False}
        if "check_vma" in inspect.signature(_shard_map).parameters
        else {"check_rep": False})
except (TypeError, ValueError):  # pragma: no cover - unintrospectable
    _SHARD_MAP_KW = {}


def moe_init(key, d_model: int, d_ff: int, n_experts: int, act: str, dtype,
             stack: tuple[int, ...] = ()):
    ks = jax.random.split(key, 4)
    pre = stack
    ps = ("layers",) * len(stack)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(
        ks[0], (*pre, d_model, n_experts), (*ps, "embed", None), dtype)
    glu = act in ("swiglu", "geglu")
    p["wi"], s["wi"] = dense_init(
        ks[1], (*pre, n_experts, d_model, d_ff),
        (*ps, "experts", None, "moe_ff"), dtype)
    if glu:
        p["wg"], s["wg"] = dense_init(
            ks[2], (*pre, n_experts, d_model, d_ff),
            (*ps, "experts", None, "moe_ff"), dtype)
    p["wo"], s["wo"] = dense_init(
        ks[3], (*pre, n_experts, d_ff, d_model),
        (*ps, "experts", "moe_ff", None), dtype)
    return p, s


def _expert_mlp(p_local, h, act):
    """p_local: (E_l, d, f) weights; h: (E_l, C, d) packed tokens."""
    up = jnp.einsum("ecd,edf->ecf", h, p_local["wi"])
    if "wg" in p_local:
        g = jnp.einsum("ecd,edf->ecf", h, p_local["wg"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        up = up * g
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, p_local["wo"])


def _moe_local_ep(p, x, *, n_experts, top_k, capacity_factor, act,
                  model_axis, token_axes, model_size):
    """Expert-parallel all_to_all body — tokens sharded over `model` too.

    x: (B_local, S_local, d) with S_local = S / model_size.  Dispatch is a
    REAL exchange (two all_to_alls of capacity buffers) instead of the
    replicated-compute + psum combine: wire per layer drops from
    2·T_l·d·(g-1)/g (the psum) to 2·k·T_l/g·d — ~8x for top-2 on a 16-way
    axis — and router/pack work stops being replicated 16x.
    This is the paper's dispatcher in miniature: pack task payloads into
    per-worker capacity buffers, ship, execute, ship back, merge.
    """
    bl, sl, d = x.shape
    t = bl * sl
    e = n_experts
    m = model_size
    e_l = p["wi"].shape[0]
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    density = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), 0)
    density_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_prob)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # capacity_factor <= 0 => dropless: capacity covers the worst case
    # (every token lists this expert in its top-k), so routing of one
    # token can never evict another's — the serving path uses this to keep
    # logits batch-composition-invariant (training keeps finite capacity).
    cap = (int(t) if capacity_factor <= 0
           else int(max(top_k, round(t * top_k / e * capacity_factor))))
    ids_flat = ids.reshape(-1)
    order = jnp.argsort(ids_flat)
    sorted_eid = ids_flat[order]
    sorted_tok = order // top_k
    sorted_gate = gates.reshape(-1)[order]
    starts = jnp.searchsorted(sorted_eid, jnp.arange(e))
    pos = jnp.arange(t * top_k) - starts[sorted_eid]
    keep = pos < cap
    slot = sorted_eid * cap + jnp.where(keep, pos, 0)

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xf[sorted_tok], 0))
    buf = buf.reshape(e, cap, d)

    # ---- ship: (E, C, d) -> (E_l, m*C, d): my experts, everyone's tokens
    recv = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1,
                              tiled=True)
    yrecv = _expert_mlp(p, recv, act)
    # ---- ship back: (E_l, m*C, d) -> (E, C, d) rows for my local tokens
    ybuf = jax.lax.all_to_all(yrecv, model_axis, split_axis=1,
                              concat_axis=0, tiled=True)

    contrib = ybuf.reshape(e * cap, d)[slot] * \
        jnp.where(keep, sorted_gate, 0.0)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[sorted_tok].add(contrib)

    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    red_axes = tuple(token_axes) + (model_axis,)
    aux, zloss, drop_frac = (jax.lax.pmean(v, red_axes)
                             for v in (aux, zloss, drop_frac))
    return y.reshape(bl, sl, d), aux, zloss, drop_frac


def _moe_local(p, x, *, n_experts, top_k, capacity_factor, act,
               model_axis, token_axes):
    """shard_map body.  x: (B_local, S, d) — replicated over `model`."""
    bl, s, d = x.shape
    t = bl * s
    e = n_experts
    xf = x.reshape(t, d)

    # ---- route (replicated compute; every model shard agrees)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)                    # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # aux losses: load balance (Switch) + router z-loss
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), 0)
    density_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * density_prob)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- pack: sort (token, k) slots by expert id
    # capacity_factor <= 0 => dropless: capacity covers the worst case
    # (every token lists this expert in its top-k), so routing of one
    # token can never evict another's — the serving path uses this to keep
    # logits batch-composition-invariant (training keeps finite capacity).
    cap = (int(t) if capacity_factor <= 0
           else int(max(top_k, round(t * top_k / e * capacity_factor))))
    ids_flat = ids.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(ids_flat)
    sorted_eid = ids_flat[order]
    sorted_tok = order // top_k
    sorted_gate = gates.reshape(-1)[order]
    starts = jnp.searchsorted(sorted_eid, jnp.arange(e))
    pos = jnp.arange(t * top_k) - starts[sorted_eid]
    keep = pos < cap
    slot = sorted_eid * cap + jnp.where(keep, pos, 0)

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xf[sorted_tok], 0))
    buf = buf.reshape(e, cap, d)

    # ---- execute only the experts this model shard owns
    midx = jax.lax.axis_index(model_axis)
    e_l = p["wi"].shape[0]                       # local expert count
    e0 = midx * e_l
    mybuf = jax.lax.dynamic_slice_in_dim(buf, e0, e_l, axis=0)
    yebuf = _expert_mlp(p, mybuf, act)           # (E_l, C, d)

    # ---- combine: scatter-add my experts' outputs, psum over model
    ybuf = jnp.zeros((e, cap, d), yebuf.dtype)
    ybuf = jax.lax.dynamic_update_slice_in_dim(ybuf, yebuf, e0, axis=0)
    contrib = ybuf.reshape(e * cap, d)[slot] * \
        jnp.where(keep, sorted_gate, 0.0)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[sorted_tok].add(contrib)
    y = jax.lax.psum(y, model_axis)

    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux, zloss, drop_frac = (
        (jax.lax.pmean(m, token_axes) if token_axes else m)
        for m in (aux, zloss, drop_frac))
    return y.reshape(bl, s, d), aux, zloss, drop_frac


def moe_apply(p, x, *, n_experts: int, top_k: int, capacity_factor: float,
              act: str, mesh=None, model_axis: str = "model",
              impl: str = "replicated"):
    """x (B,S,d) -> (y (B,S,d), metrics dict).  Requires a mesh (a (1,1)
    trivial mesh is built for un-meshed CPU smoke runs).

    impl: "replicated" — activations replicated over `model`, psum combine
          (the TP-compatible baseline); "ep_a2a" — tokens seq-sharded over
          `model`, two all_to_alls (the §Perf expert-parallel path).
    """
    if mesh is None:
        from ..sharding import current_rules
        rules = current_rules()
        if rules is not None:
            mesh = rules.mesh
        else:
            import numpy as np
            mesh = jax.sharding.Mesh(
                np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    token_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bdim = token_axes if token_axes else None
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))[model_axis]

    pspec = {k: P(model_axis, *(None,) * (v.ndim - 1)) for k, v in p.items()
             if k != "router"}
    pspec["router"] = P()

    if impl == "ep_a2a" and x.shape[1] % msize == 0:
        body = functools.partial(
            _moe_local_ep, n_experts=n_experts, top_k=top_k,
            capacity_factor=capacity_factor, act=act,
            model_axis=model_axis, token_axes=token_axes, model_size=msize)
        y, aux, zloss, drop = _shard_map(
            body, mesh=mesh,
            in_specs=(pspec, P(bdim, model_axis, None)),
            out_specs=(P(bdim, model_axis, None), P(), P(), P()),
            **_SHARD_MAP_KW,
        )(p, x)
    else:
        body = functools.partial(
            _moe_local, n_experts=n_experts, top_k=top_k,
            capacity_factor=capacity_factor, act=act,
            model_axis=model_axis, token_axes=token_axes)
        y, aux, zloss, drop = _shard_map(
            body, mesh=mesh,
            in_specs=(pspec, P(bdim, None, None)),
            out_specs=(P(bdim, None, None), P(), P(), P()),
            **_SHARD_MAP_KW,
        )(p, x)
    metrics = {"moe_aux": aux, "moe_zloss": zloss, "moe_drop": drop}
    return y, metrics
