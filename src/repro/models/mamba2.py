"""Mamba2 block (zamba2's SSM component): in-proj, causal depthwise conv,
SSD scan (kernels/mamba2_ssd), gated RMSNorm, out-proj.

Conv is expressed as W static shifts (W=4) — cheap, and each of x/B/C gets
its own conv so the TP-sharded d_inner stream never concatenates with the
replicated B/C streams.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.mamba2_ssd import ssd, ssd_decode
from ..sharding import shard
from .layers import dense_init, rms_norm


def mamba2_init(key, d_model: int, *, expand: int, state_dim: int,
                head_dim: int, conv_width: int, dtype,
                stack: tuple[int, ...] = ()):
    d_in = expand * d_model
    n_heads = d_in // head_dim
    g = 1                                    # B/C groups
    ks = jax.random.split(key, 8)
    pre, ps = stack, ("layers",) * len(stack)
    p, s = {}, {}
    p["wz"], s["wz"] = dense_init(ks[0], (*pre, d_model, d_in),
                                  (*ps, "embed", "inner"), dtype)
    p["wx"], s["wx"] = dense_init(ks[1], (*pre, d_model, d_in),
                                  (*ps, "embed", "inner"), dtype)
    p["wB"], s["wB"] = dense_init(ks[2], (*pre, d_model, g, state_dim),
                                  (*ps, "embed", None, None), dtype)
    p["wC"], s["wC"] = dense_init(ks[3], (*pre, d_model, g, state_dim),
                                  (*ps, "embed", None, None), dtype)
    p["wdt"], s["wdt"] = dense_init(ks[4], (*pre, d_model, n_heads),
                                    (*ps, "embed", "inner"), dtype)
    p["dt_bias"] = jnp.zeros((*pre, n_heads), dtype)
    s["dt_bias"] = (*ps, "inner")
    # A_log in [log 0.5, log 8] (mamba2 default init range)
    p["A_log"] = jnp.log(jnp.linspace(0.5, 8.0, n_heads, dtype=jnp.float32)
                         ).astype(dtype) * jnp.ones((*pre, n_heads), dtype)
    s["A_log"] = (*ps, "inner")
    p["D"] = jnp.ones((*pre, n_heads), dtype)
    s["D"] = (*ps, "inner")
    for nm, ch in (("conv_x", d_in), ("conv_B", g * state_dim),
                   ("conv_C", g * state_dim)):
        p[nm], s[nm] = dense_init(
            ks[5], (*pre, conv_width, ch),
            (*ps, "conv", "inner" if nm == "conv_x" else None), dtype,
            scale=1.0 / conv_width)
    p["norm"] = jnp.zeros((*pre, d_in), dtype)
    s["norm"] = (*ps, "inner")
    p["wo"], s["wo"] = dense_init(ks[6], (*pre, d_in, d_model),
                                  (*ps, "inner", "embed"), dtype)
    return p, s


def _conv_shift(w, x):
    """Causal depthwise conv as static shifts.  w (W, C); x (B, S, C)."""
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[-1 - i]
    return out


def _conv_step(w, state, xt):
    """state (B, W-1, C); xt (B, 1, C) -> (yt (B, 1, C), new state)."""
    full = jnp.concatenate([state, xt], axis=1)           # (B, W, C)
    yt = jnp.einsum("bwc,wc->bc", full, w)[:, None]
    return yt, full[:, 1:]


def _inner(p, x, *, head_dim):
    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xc = jnp.einsum("bsd,di->bsi", x, p["wx"])
    Bc = jnp.einsum("bsd,dgn->bsgn", x, p["wB"])
    Cc = jnp.einsum("bsd,dgn->bsgn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    return z, xc, Bc, Cc, dt


def mamba2_apply(p, x, *, head_dim: int, chunk: int = 64, impl: str = "chunked",
                 rms_eps: float = 1e-6, mask=None):
    """Train/prefill path.  x (B,S,d) -> (y, final_state (conv+ssd)).

    ``mask`` (B,S) bool: True at real-token slots of a left-padded batch.
    Pad steps become identity transitions — their conv-tap inputs are
    zeroed (a real token near the boundary convolves over zeros, exactly
    the decode path's fresh conv state) and dt is gated to 0 so the SSD
    recurrence neither decays nor absorbs anything on a pad step.  The
    returned conv/ssd states are therefore batch-composition-invariant.
    """
    b, s_len, d = x.shape
    z, xc, Bc, Cc, dt = _inner(p, x, head_dim=head_dim)
    g, n = Bc.shape[-2:]
    if mask is not None:
        m = mask[..., None].astype(xc.dtype)
        xc = xc * m
        Bc = Bc * m[..., None]
        Cc = Cc * m[..., None]

    conv_in = (xc, Bc.reshape(b, s_len, g * n), Cc.reshape(b, s_len, g * n))
    xc = jax.nn.silu(_conv_shift(p["conv_x"], conv_in[0]))
    Bc = jax.nn.silu(_conv_shift(p["conv_B"], conv_in[1])).reshape(
        b, s_len, g, n)
    Cc = jax.nn.silu(_conv_shift(p["conv_C"], conv_in[2])).reshape(
        b, s_len, g, n)

    h = xc.shape[-1] // head_dim
    xh = xc.reshape(b, s_len, h, head_dim)
    xh = shard(xh, "act_batch", "act_seq", "act_inner", None)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    if mask is not None:
        # dt=0 on pad steps => decay exp(dt*A)=1 and input contribution 0:
        # the SSD state passes through pad slots unchanged
        dt = dt * mask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, ssd_state = ssd(xh, dt, A, Bc, Cc, chunk=chunk, impl=impl)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s_len, h * head_dim)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    conv_tail = tuple(
        jnp.pad(ci, ((0, 0), (max(0, w.shape[0] - 1 - ci.shape[1]), 0),
                     (0, 0)))[:, -(w.shape[0] - 1):]
        for ci, w in zip(conv_in, (p["conv_x"], p["conv_B"], p["conv_C"])))
    return shard(out, "act_batch", "act_seq", "act_embed"), \
        {"conv": conv_tail, "ssd": ssd_state}


def mamba2_decode(p, x, state, *, head_dim: int, rms_eps: float = 1e-6):
    """One token.  x (B,1,d); state {conv: (cx,cB,cC), ssd: (B,H,P,N)}."""
    b = x.shape[0]
    z, xc, Bc, Cc, dt = _inner(p, x, head_dim=head_dim)
    g, n = Bc.shape[-2:]

    cx, cB, cC = state["conv"]
    xc, cx = _conv_step(p["conv_x"], cx, xc)
    Bc2, cB = _conv_step(p["conv_B"], cB, Bc.reshape(b, 1, g * n))
    Cc2, cC = _conv_step(p["conv_C"], cC, Cc.reshape(b, 1, g * n))
    xc = jax.nn.silu(xc)
    Bc = jax.nn.silu(Bc2).reshape(b, g, n)
    Cc = jax.nn.silu(Cc2).reshape(b, g, n)

    h = xc.shape[-1] // head_dim
    xh = xc.reshape(b, h, head_dim)
    dt = jax.nn.softplus(dt + p["dt_bias"])[:, 0]          # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, ssd_state = ssd_decode(xh, dt, A, Bc, Cc, state["ssd"])
    y = y + p["D"].astype(y.dtype)[None, :, None] * xh
    y = y.reshape(b, 1, h * head_dim)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    return out, {"conv": (cx, cB, cC), "ssd": ssd_state}
