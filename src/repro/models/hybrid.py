"""Zamba2-style hybrid: a stack of Mamba2 blocks with ONE shared attention
block applied every k blocks, modulated per application by LoRA deltas.

The shared block consumes concat(x, x0) (current hidden + original
embedding, zamba2's re-injection trick) and projects back to d_model.
Weight sharing means the attention params are closed over by the group-scan
body (one HBM copy); only the per-group LoRA (9 × rank·d) is scanned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.decode_attention import decode_attention
from ..kernels.flash_attention import attention
from ..sharding import shard
from .layers import apply_rope, dense_init, embed_apply, embed_init, \
    mlp_apply, mlp_init, pad_mask, ragged_positions, rms_norm
from .mamba2 import mamba2_apply, mamba2_decode, mamba2_init
from .stacking import scan_layers


def _n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def hybrid_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 10)
    dt = jnp.dtype(cfg.param_dtype)
    L, G = cfg.n_layers, _n_groups(cfg)
    d, r = cfg.d_model, cfg.shared_attn_lora_rank
    p, s = {}, {}
    p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, d, dt)

    mp, ms = mamba2_init(ks[1], d, expand=cfg.ssm.expand,
                         state_dim=cfg.ssm.state_dim,
                         head_dim=cfg.ssm.head_dim,
                         conv_width=cfg.ssm.conv_width, dtype=dt, stack=(L,))
    mln = jnp.zeros((L, d), dt)
    p["mamba"], s["mamba"] = {"ln": mln, **mp}, \
        {"ln": ("layers", "embed"), **ms}

    # shared attention block on concat(x, x0) -> d
    ap, asx = {}, {}
    ap["ln"] = jnp.zeros((2 * d,), dt)
    asx["ln"] = ("embed",)
    ap["wq"], asx["wq"] = dense_init(
        ks[2], (2 * d, cfg.n_heads, cfg.head_dim),
        ("embed", "heads", "head_dim"), dt)
    ap["wk"], asx["wk"] = dense_init(
        ks[3], (2 * d, cfg.n_kv_heads, cfg.head_dim),
        ("embed", "kv_heads", "head_dim"), dt)
    ap["wv"], asx["wv"] = dense_init(
        ks[4], (2 * d, cfg.n_kv_heads, cfg.head_dim),
        ("embed", "kv_heads", "head_dim"), dt)
    ap["wo"], asx["wo"] = dense_init(
        ks[5], (cfg.n_heads, cfg.head_dim, d),
        ("heads", "head_dim", "embed"), dt)
    ap["ln2"] = jnp.zeros((2 * d,), dt)
    asx["ln2"] = ("embed",)
    mlp_p, mlp_s = mlp_init(ks[6], 2 * d, cfg.d_ff, cfg.act, dt)
    # project the GLU output back to d (input was 2d)
    mlp_p["wo"], mlp_s["wo"] = dense_init(
        ks[7], (cfg.d_ff, d), ("mlp", "embed"), dt)
    ap["mlp"], asx["mlp"] = mlp_p, mlp_s
    p["shared"], s["shared"] = ap, asx

    # per-application LoRA deltas on wq/wo
    lora_p, lora_s = {}, {}
    lora_p["qa"], lora_s["qa"] = dense_init(
        ks[8], (G, 2 * d, r), ("group", "embed", "lora"), dt)
    lora_p["qb"] = jnp.zeros((G, r, cfg.n_heads * cfg.head_dim), dt)
    lora_s["qb"] = ("group", "lora", None)
    lora_p["oa"], lora_s["oa"] = dense_init(
        ks[9], (G, cfg.n_heads * cfg.head_dim, r),
        ("group", None, "lora"), dt)
    lora_p["ob"] = jnp.zeros((G, r, d), dt)
    lora_s["ob"] = ("group", "lora", None)
    p["lora"], s["lora"] = lora_p, lora_s

    p["final_norm"] = jnp.zeros((d,), dt)
    s["final_norm"] = ("embed",)
    p["unembed"], s["unembed"] = embed_init(ks[0], cfg.vocab_size, d, dt)
    return p, s


def _shared_qkv(ap, lora, u, positions, cfg, pos_offset=None):
    """Project concat-input u (B,S,2d) -> q/k/v with per-group LoRA on q."""
    q = jnp.einsum("bsd,dhk->bshk", u, ap["wq"])
    dq = jnp.einsum("bsd,dr->bsr", u, lora["qa"])
    dq = jnp.einsum("bsr,ra->bsa", dq, lora["qb"])
    q = q + dq.reshape(q.shape)
    k = jnp.einsum("bsd,dhk->bshk", u, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", u, ap["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _shared_out(ap, lora, o):
    """o (B,S,H,D) -> (B,S,d) with LoRA on the output proj."""
    b, s_len = o.shape[:2]
    out = jnp.einsum("bshk,hkd->bsd", o, ap["wo"])
    flat = o.reshape(b, s_len, -1)
    do = jnp.einsum("bsa,ar->bsr", flat, lora["oa"])
    out = out + jnp.einsum("bsr,rd->bsd", do, lora["ob"])
    return out


def _shared_block(ap, lora, x, x0, positions, cfg, attn_impl,
                  return_kv=False, kv_start=None):
    u = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(u, ap["ln"], cfg.rms_eps)
    q, k, v = _shared_qkv(ap, lora, h, positions, cfg)
    o = attention(q, k, v, causal=True, window=cfg.window, impl=attn_impl,
                  kv_start=kv_start)
    x = x + _shared_out(ap, lora, o)
    h = rms_norm(jnp.concatenate([x, x0], axis=-1), ap["ln2"], cfg.rms_eps)
    x = x + mlp_apply(ap["mlp"], h, cfg.act)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    if return_kv:
        return x, (k, v)
    return x


def hybrid_forward(p, cfg: ModelConfig, tokens, attn_impl: str = "ref",
                   ssm_impl: str = "chunked", collect_cache: bool = False,
                   last_only: bool = False, lengths=None):
    """``lengths`` (B,) int32: real-token count per left-padded row.  Pad
    slots are identity transitions for the mamba conv/SSD state and masked
    keys for the shared attention, so outputs at real positions (and the
    collected caches) are batch-composition-invariant."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_apply(p["embed"], tokens).astype(dt)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    x0 = x
    b, s_len = x.shape[:2]
    positions, kv_start = ragged_positions(lengths, b, s_len)
    mask = None if lengths is None else pad_mask(lengths, s_len)
    G, k_every = _n_groups(cfg), cfg.shared_attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape(G, k_every, *a.shape[1:]), p["mamba"])

    def group_body(x, xs):
        mparams, lora = xs

        def mamba_body(x, lp):
            h = rms_norm(x, lp["ln"], cfg.rms_eps)
            h, st = mamba2_apply(
                {k: v for k, v in lp.items() if k != "ln"}, h,
                head_dim=cfg.ssm.head_dim, chunk=cfg.ssm.chunk,
                impl=ssm_impl, rms_eps=cfg.rms_eps, mask=mask)
            return x + h, (st if collect_cache else 0)

        x, msts = scan_layers(mamba_body, x, mparams,
                              use_scan=cfg.scan_layers)
        if collect_cache:
            x, (ck, cv) = _shared_block(p["shared"], lora, x, x0, positions,
                                        cfg, attn_impl, return_kv=True,
                                        kv_start=kv_start)
            cdt = jnp.dtype(cfg.param_dtype)
            return x, (msts, (ck.astype(cdt), cv.astype(cdt)))
        x = _shared_block(p["shared"], lora, x, x0, positions, cfg,
                          attn_impl, kv_start=kv_start)
        return x, 0

    body = group_body
    if cfg.remat != "none" and not collect_cache:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots_saveable" else None)
        body = jax.checkpoint(group_body, policy=policy)
    x, caches = scan_layers(body, x, (grouped, p["lora"]),
                             use_scan=cfg.scan_layers)
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("...d,vd->...v", x, p["unembed"])
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    logits = logits.astype(jnp.float32) if cfg.logits_fp32 else logits
    if collect_cache:
        return logits, caches
    return logits, {}


def hybrid_init_cache(cfg: ModelConfig, batch: int, cap: int,
                      filled: int | None = None, start=None):
    cdt = jnp.dtype(cfg.param_dtype)
    L, G = cfg.n_layers, _n_groups(cfg)
    d_in = cfg.ssm.expand * cfg.d_model
    h = d_in // cfg.ssm.head_dim
    w1 = cfg.ssm.conv_width - 1
    gn = cfg.ssm.state_dim
    idx = cap - 1 if filled is None else filled
    if start is None:
        start = jnp.zeros((batch,), jnp.int32)
    return {
        "conv_x": jnp.zeros((L, batch, w1, d_in), cdt),
        "conv_B": jnp.zeros((L, batch, w1, gn), cdt),
        "conv_C": jnp.zeros((L, batch, w1, gn), cdt),
        "ssd": jnp.zeros((L, batch, h, cfg.ssm.head_dim, cfg.ssm.state_dim),
                         jnp.float32),
        "k": jnp.zeros((G, batch, cap, cfg.n_kv_heads, cfg.head_dim), cdt),
        "v": jnp.zeros((G, batch, cap, cfg.n_kv_heads, cfg.head_dim), cdt),
        "idx": jnp.int32(idx),
        "start": start,
    }


def hybrid_decode(p, cfg: ModelConfig, cache, tokens,
                  attn_impl: str = "ref"):
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_apply(p["embed"], tokens).astype(dt)
    x0 = x
    b = x.shape[0]
    idx = cache["idx"]
    start = cache.get("start")               # (B,) left-pad counts, or None
    positions = jnp.full((b, 1), idx, jnp.int32)
    if start is not None:
        positions = positions - start[:, None].astype(jnp.int32)
    G, k_every = _n_groups(cfg), cfg.shared_attn_every
    grouped = jax.tree.map(
        lambda a: a.reshape(G, k_every, *a.shape[1:]), p["mamba"])
    gcache = {k: cache[k].reshape(G, k_every, *cache[k].shape[1:])
              for k in ("conv_x", "conv_B", "conv_C", "ssd")}

    def group_body(x, xs):
        mparams, lora, mc, ck, cv = xs

        def mamba_body(x, xs2):
            lp, cx, cb, cc, st = xs2
            h = rms_norm(x, lp["ln"], cfg.rms_eps)
            h, new = mamba2_decode(
                {k: v for k, v in lp.items() if k != "ln"}, h,
                {"conv": (cx, cb, cc), "ssd": st},
                head_dim=cfg.ssm.head_dim, rms_eps=cfg.rms_eps)
            return x + h, (*new["conv"], new["ssd"])

        x, mnew = scan_layers(
            mamba_body, x,
            (mparams, mc["conv_x"], mc["conv_B"], mc["conv_C"], mc["ssd"]),
            use_scan=cfg.scan_layers)

        u = jnp.concatenate([x, x0], axis=-1)
        h = rms_norm(u, p["shared"]["ln"], cfg.rms_eps)
        q, k, v = _shared_qkv(p["shared"], lora, h, positions, cfg)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, idx, 0, 0))
        kv_len = jnp.full((b,), idx + 1, jnp.int32)
        o = decode_attention(q[:, 0], ck, cv, kv_len, window=cfg.window,
                             impl=attn_impl, kv_start=start)[:, None]
        x = x + _shared_out(p["shared"], lora, o)
        h2 = rms_norm(jnp.concatenate([x, x0], axis=-1),
                      p["shared"]["ln2"], cfg.rms_eps)
        x = x + mlp_apply(p["shared"]["mlp"], h2, cfg.act)
        return x, (mnew, ck, cv)

    x, (mnew, ck, cv) = scan_layers(
        group_body, x,
        (grouped, p["lora"], gcache, cache["k"], cache["v"]),
        use_scan=cfg.scan_layers)
    x = rms_norm(x, p["final_norm"], cfg.rms_eps)
    logits = jnp.einsum("...d,vd->...v", x[:, -1], p["unembed"])
    logits = logits.astype(jnp.float32) if cfg.logits_fp32 else logits
    newc = {
        "conv_x": mnew[0].reshape(cache["conv_x"].shape),
        "conv_B": mnew[1].reshape(cache["conv_B"].shape),
        "conv_C": mnew[2].reshape(cache["conv_C"].shape),
        "ssd": mnew[3].reshape(cache["ssd"].shape),
        "k": ck, "v": cv, "idx": idx + 1,
    }
    if start is not None:
        newc["start"] = start
    return logits, newc
