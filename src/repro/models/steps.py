"""Entry-point builders: train_step / prefill_step / decode_step.

These are the functions the launcher lowers against the production mesh —
each one is an "alternative entry point" (paper §3.1): same model source,
separately compiled programs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..optim.adamw import AdamW
from ..sharding import shard
from .api import Model


def cross_entropy(logits, labels, *, z_weight: float = 1e-4):
    """Mean next-token xent over valid (label >= 0) positions + z-loss."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(valid), 1.0)
    loss = jnp.sum(nll * valid) / n
    zloss = jnp.sum(jnp.square(logz) * valid) / n
    return loss + z_weight * zloss, loss


def make_loss_fn(model: Model, aux_weight: float = 0.01):
    def loss_fn(params, batch):
        logits, metrics = model.forward(params, batch)
        total, xent = cross_entropy(logits, batch["labels"])
        if "moe_aux" in metrics:
            total = total + aux_weight * metrics["moe_aux"] \
                + 1e-3 * metrics.get("moe_zloss", 0.0)
        return total, {"xent": xent, **metrics}
    return loss_fn


def make_train_step(model: Model, opt: AdamW):
    """(params, opt_state, batch) -> (params', opt_state', metrics)."""
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens):
        logits, cache = model.decode(params, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache
    return decode_step
