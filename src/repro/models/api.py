"""Unified model API: one ``Model`` facade per architecture family.

  model.init(key)                  -> (params, logical_specs)
  model.forward(params, batch)     -> (logits (B,S,V), metrics)   [train]
  model.prefill(params, batch)     -> (last logits (B,V), cache)
  model.decode(params, cache, tok) -> (logits (B,V), cache')
  model.init_cache(batch, cap)     -> family-specific cache pytree

Ragged batches: ``batch["lengths"]`` (B,) int32 marks how many REAL tokens
each left-padded row holds (see ``runtime/server.pack_prompts``).  Every
family masks pad slots out of attention / gates them out of recurrent
state, and attention-family caches carry the per-row first valid slot as
``cache["start"]`` so decode keeps masking them — greedy decode of a
prompt is invariant to the batch it was packed into.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
entry-point input — the shape-only payloads the dry-run lowers against
(no allocation), mirroring how Cppless deploys against abstract payloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, hybrid, rwkv_model, transformer


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def _attn_impl(cfg: ModelConfig) -> str:
    """pallas on the TPU runtime; the query-chunked XLA path elsewhere
    (same math, flash-like memory; SPMD-partitionable, unlike interpret)."""
    if cfg.attn_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return cfg.attn_impl


def build_model(cfg: ModelConfig) -> Model:
    impl = _attn_impl(cfg)

    if cfg.family in ("dense", "moe", "vlm"):
        def forward(p, batch):
            return transformer.lm_forward(
                p, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), pos3d=batch.get("pos3d"),
                attn_impl=impl, lengths=batch.get("lengths"))

        def prefill(p, batch):
            return transformer.lm_prefill(
                p, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), pos3d=batch.get("pos3d"),
                attn_impl=impl, lengths=batch.get("lengths"))

        def decode(p, cache, tokens):
            return transformer.lm_decode(p, cfg, cache, tokens,
                                         attn_impl=impl)

        return Model(cfg, lambda k: transformer.lm_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: transformer.lm_init_cache(
                         cfg, b, cap, **kw))

    if cfg.family == "hybrid":
        def forward(p, batch):
            return hybrid.hybrid_forward(p, cfg, batch["tokens"],
                                         attn_impl=impl,
                                         lengths=batch.get("lengths"))

        def prefill(p, batch):
            lengths = batch.get("lengths")
            logits, caches = hybrid.hybrid_forward(
                p, cfg, batch["tokens"], attn_impl=impl,
                collect_cache=True, last_only=True, lengths=lengths)
            msts, (ck, cv) = caches
            b, s_len = batch["tokens"].shape

            def _flat(a):   # (G, k, ...) -> (L, ...)
                return a.reshape(a.shape[0] * a.shape[1], *a.shape[2:])

            cache = {
                "conv_x": _flat(msts["conv"][0]),
                "conv_B": _flat(msts["conv"][1]),
                "conv_C": _flat(msts["conv"][2]),
                "ssd": _flat(msts["ssd"]), "k": ck, "v": cv,
                "idx": jnp.int32(s_len),
                "start": (jnp.zeros((b,), jnp.int32) if lengths is None
                          else (s_len - lengths).astype(jnp.int32)),
            }
            return logits[:, -1], cache

        def decode(p, cache, tokens):
            return hybrid.hybrid_decode(p, cfg, cache, tokens,
                                        attn_impl=impl)

        return Model(cfg, lambda k: hybrid.hybrid_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: hybrid.hybrid_init_cache(
                         cfg, b, cap, **kw))

    if cfg.family == "ssm":
        def forward(p, batch):
            return rwkv_model.rwkv_forward(p, cfg, batch["tokens"],
                                           lengths=batch.get("lengths"))

        def prefill(p, batch):
            logits, cache = rwkv_model.rwkv_forward(
                p, cfg, batch["tokens"], collect_cache=True, last_only=True,
                lengths=batch.get("lengths"))
            return logits[:, -1], cache

        def decode(p, cache, tokens):
            return rwkv_model.rwkv_decode(p, cfg, cache, tokens)

        return Model(cfg, lambda k: rwkv_model.rwkv_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: rwkv_model.rwkv_init_cache(
                         cfg, b, cap, **kw))

    if cfg.family == "encdec":
        def forward(p, batch):
            enc = encdec.encode(p, cfg, batch["frames"], attn_impl=impl)
            logits, _ = encdec.decode_train(p, cfg, batch["tokens"], enc,
                                            attn_impl=impl)
            return logits, {}

        def prefill(p, batch):
            enc = encdec.encode(p, cfg, batch["frames"], attn_impl=impl)
            logits, cache = encdec.decode_train(
                p, cfg, batch["tokens"], enc, attn_impl=impl,
                collect_cache=True, last_only=True)
            return logits[:, -1], cache

        def decode(p, cache, tokens):
            return encdec.encdec_decode(p, cfg, cache, tokens,
                                        attn_impl=impl)

        return Model(cfg, lambda k: encdec.encdec_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: encdec.encdec_init_cache(
                         cfg, b, cap, **kw))

    raise ValueError(f"unknown family {cfg.family}")


# ------------------------------------------------------------ input specs --

def cache_specs(cfg: ModelConfig):
    """Logical-axis tree for the family's cache pytree (mirrors init_cache).

    ``act_kv_seq`` defaults to replicated; re-mapping it to a mesh axis is
    the flash-decode sequence-parallel hillclimb lever.
    """
    kv = ("layers", "act_batch", "act_kv_seq", "act_kv_heads", None)
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.kv_quant == "int8":
            sc = ("layers", "act_batch", "act_kv_seq", "act_kv_heads")
            return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc,
                    "idx": (), "start": ("act_batch",)}
        return {"k": kv, "v": kv, "idx": (), "start": ("act_batch",)}
    if cfg.family == "hybrid":
        gkv = ("group", "act_batch", "act_kv_seq", "act_kv_heads", None)
        return {"conv_x": ("layers", "act_batch", None, "act_inner"),
                "conv_B": ("layers", "act_batch", None, None),
                "conv_C": ("layers", "act_batch", None, None),
                "ssd": ("layers", "act_batch", "act_inner", None, None),
                "k": gkv, "v": gkv, "idx": (), "start": ("act_batch",)}
    if cfg.family == "ssm":
        return {"wkv": ("layers", "act_batch", "act_inner", None, None),
                "shift_att": ("layers", "act_batch", "act_embed"),
                "shift_ffn": ("layers", "act_batch", "act_embed"),
                "idx": ()}
    if cfg.family == "encdec":
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv, "idx": ()}
    raise ValueError(cfg.family)


def grow_cache(cfg: ModelConfig, cache, new_cap: int):
    """Pad the seq-capacity dimension of a prefill cache so decode can
    append: dynamic_update_slice clamps out-of-range starts, so writing
    token S into a capacity-S cache silently corrupts the last slot."""
    if cfg.family == "ssm":
        return cache                                # O(1) state, no seq dim
    out = dict(cache)
    for k in ("k", "v", "k_scale", "v_scale"):      # NOT cross_k/v (static)
        if k not in cache:
            continue
        a = cache[k]
        pad = new_cap - a.shape[2]
        if pad > 0:
            widths = [(0, 0)] * a.ndim
            widths[2] = (0, pad)
            out[k] = jnp.pad(a, widths)
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract entry-point inputs for one (arch × shape) cell.

    train/prefill -> {"batch": {...}};  decode -> {"cache": ..., "tokens"}.
    Modality frontends are stubs: vlm/audio cells receive precomputed
    patch/frame embeddings (embeds_input), per the assignment.
    """
    b, s = shape.global_batch, shape.seq_len
    cdt = cfg.compute_dtype
    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, s, cfg.d_model), cdt)
            batch["tokens"] = _sds((b, s), "int32")
        elif cfg.embeds_input:
            batch["embeds"] = _sds((b, s, cfg.d_model), cdt)
            if cfg.mrope_sections:
                batch["pos3d"] = _sds((3, b, s), "int32")
        else:
            batch["tokens"] = _sds((b, s), "int32")
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), "int32")
        return {"batch": batch}

    # decode: one new token against a cache of capacity seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {"cache": cache, "tokens": _sds((b, 1), "int32")}
