"""Unified model API: one ``Model`` facade per architecture family.

  model.init(key)                  -> (params, logical_specs)
  model.forward(params, batch)     -> (logits (B,S,V), metrics)   [train]
  model.prefill(params, batch)     -> (last logits (B,V), cache)
  model.decode(params, cache, tok) -> (logits (B,V), cache')
  model.init_cache(batch, cap)     -> family-specific cache pytree

Ragged batches: ``batch["lengths"]`` (B,) int32 marks how many REAL tokens
each left-padded row holds (see ``runtime/server.pack_prompts``).  Every
family masks pad slots out of attention / gates them out of recurrent
state, and attention-family caches carry the per-row first valid slot as
``cache["start"]`` so decode keeps masking them — greedy decode of a
prompt is invariant to the batch it was packed into.

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
entry-point input — the shape-only payloads the dry-run lowers against
(no allocation), mirroring how Cppless deploys against abstract payloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, hybrid, rwkv_model, transformer


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def _attn_impl(cfg: ModelConfig) -> str:
    """pallas on the TPU runtime; the query-chunked XLA path elsewhere
    (same math, flash-like memory; SPMD-partitionable, unlike interpret)."""
    if cfg.attn_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return cfg.attn_impl


def build_model(cfg: ModelConfig) -> Model:
    impl = _attn_impl(cfg)

    if cfg.family in ("dense", "moe", "vlm"):
        def forward(p, batch):
            return transformer.lm_forward(
                p, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), pos3d=batch.get("pos3d"),
                attn_impl=impl, lengths=batch.get("lengths"))

        def prefill(p, batch):
            return transformer.lm_prefill(
                p, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), pos3d=batch.get("pos3d"),
                attn_impl=impl, lengths=batch.get("lengths"))

        def decode(p, cache, tokens):
            return transformer.lm_decode(p, cfg, cache, tokens,
                                         attn_impl=impl)

        return Model(cfg, lambda k: transformer.lm_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: transformer.lm_init_cache(
                         cfg, b, cap, **kw))

    if cfg.family == "hybrid":
        def forward(p, batch):
            return hybrid.hybrid_forward(p, cfg, batch["tokens"],
                                         attn_impl=impl,
                                         lengths=batch.get("lengths"))

        def prefill(p, batch):
            lengths = batch.get("lengths")
            logits, caches = hybrid.hybrid_forward(
                p, cfg, batch["tokens"], attn_impl=impl,
                collect_cache=True, last_only=True, lengths=lengths)
            msts, (ck, cv) = caches
            b, s_len = batch["tokens"].shape

            def _flat(a):   # (G, k, ...) -> (L, ...)
                return a.reshape(a.shape[0] * a.shape[1], *a.shape[2:])

            cache = {
                "conv_x": _flat(msts["conv"][0]),
                "conv_B": _flat(msts["conv"][1]),
                "conv_C": _flat(msts["conv"][2]),
                "ssd": _flat(msts["ssd"]), "k": ck, "v": cv,
                "idx": jnp.int32(s_len),
                "start": (jnp.zeros((b,), jnp.int32) if lengths is None
                          else (s_len - lengths).astype(jnp.int32)),
            }
            return logits[:, -1], cache

        def decode(p, cache, tokens):
            return hybrid.hybrid_decode(p, cfg, cache, tokens,
                                        attn_impl=impl)

        return Model(cfg, lambda k: hybrid.hybrid_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: hybrid.hybrid_init_cache(
                         cfg, b, cap, **kw))

    if cfg.family == "ssm":
        def forward(p, batch):
            return rwkv_model.rwkv_forward(p, cfg, batch["tokens"],
                                           lengths=batch.get("lengths"))

        def prefill(p, batch):
            logits, cache = rwkv_model.rwkv_forward(
                p, cfg, batch["tokens"], collect_cache=True, last_only=True,
                lengths=batch.get("lengths"))
            return logits[:, -1], cache

        def decode(p, cache, tokens):
            return rwkv_model.rwkv_decode(p, cfg, cache, tokens)

        return Model(cfg, lambda k: rwkv_model.rwkv_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: rwkv_model.rwkv_init_cache(
                         cfg, b, cap, **kw))

    if cfg.family == "encdec":
        def forward(p, batch):
            enc = encdec.encode(p, cfg, batch["frames"], attn_impl=impl)
            logits, _ = encdec.decode_train(p, cfg, batch["tokens"], enc,
                                            attn_impl=impl)
            return logits, {}

        def prefill(p, batch):
            enc = encdec.encode(p, cfg, batch["frames"], attn_impl=impl)
            logits, cache = encdec.decode_train(
                p, cfg, batch["tokens"], enc, attn_impl=impl,
                collect_cache=True, last_only=True)
            return logits[:, -1], cache

        def decode(p, cache, tokens):
            return encdec.encdec_decode(p, cfg, cache, tokens,
                                        attn_impl=impl)

        return Model(cfg, lambda k: encdec.encdec_init(k, cfg), forward,
                     prefill, decode,
                     lambda b, cap, **kw: encdec.encdec_init_cache(
                         cfg, b, cap, **kw))

    raise ValueError(f"unknown family {cfg.family}")


# ------------------------------------------------------------ input specs --

def cache_specs(cfg: ModelConfig):
    """Logical-axis tree for the family's cache pytree (mirrors init_cache).

    ``act_kv_seq`` defaults to replicated; re-mapping it to a mesh axis is
    the flash-decode sequence-parallel hillclimb lever.
    """
    kv = ("layers", "act_batch", "act_kv_seq", "act_kv_heads", None)
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.kv_quant == "int8":
            sc = ("layers", "act_batch", "act_kv_seq", "act_kv_heads")
            return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc,
                    "idx": (), "start": ("act_batch",)}
        return {"k": kv, "v": kv, "idx": (), "start": ("act_batch",)}
    if cfg.family == "hybrid":
        gkv = ("group", "act_batch", "act_kv_seq", "act_kv_heads", None)
        return {"conv_x": ("layers", "act_batch", None, "act_inner"),
                "conv_B": ("layers", "act_batch", None, None),
                "conv_C": ("layers", "act_batch", None, None),
                "ssd": ("layers", "act_batch", "act_inner", None, None),
                "k": gkv, "v": gkv, "idx": (), "start": ("act_batch",)}
    if cfg.family == "ssm":
        return {"wkv": ("layers", "act_batch", "act_inner", None, None),
                "shift_att": ("layers", "act_batch", "act_embed"),
                "shift_ffn": ("layers", "act_batch", "act_embed"),
                "idx": ()}
    if cfg.family == "encdec":
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv, "idx": ()}
    raise ValueError(cfg.family)


def grow_cache(cfg: ModelConfig, cache, new_cap: int, bucket: bool = True):
    """Pad the seq-capacity dimension of a prefill cache so decode can
    append: dynamic_update_slice clamps out-of-range starts, so writing
    token S into a capacity-S cache silently corrupts the last slot.

    ``bucket`` rounds the grown capacity up to the next power of two.
    Entry-point identity fingerprints every cache shape, so exact-fit
    growth compiles a fresh decode program per distinct ``s + max_new`` —
    pow2 buckets make nearby lengths share one compiled entry point (at
    worst 2x padded capacity, whose extra slots are masked out of
    attention exactly like left pad)."""
    if cfg.family == "ssm":
        return cache                                # O(1) state, no seq dim
    if bucket:
        new_cap = 1 << max(0, int(new_cap) - 1).bit_length()
    out = dict(cache)
    for k in ("k", "v", "k_scale", "v_scale"):      # NOT cross_k/v (static)
        if k not in cache:
            continue
        a = cache[k]
        pad = new_cap - a.shape[2]
        if pad > 0:
            widths = [(0, 0)] * a.ndim
            widths[2] = (0, pad)
            out[k] = jnp.pad(a, widths)
    return out


# ------------------------------------------------- slot-arena primitives --
# Iteration-level serving (ISSUE 5) keeps one *arena* cache resident on a
# worker: a batch of B row slots sharing one write cursor ``idx``.  A row
# prefilled separately (in its own width-s buffer) drops into a slot by
# aligning its content so the last real token sits at ``idx - 1`` and
# setting the row's ``start`` to ``idx - length`` — exactly the left-pad
# layout PR 4's masks already handle, so a newly admitted request never
# touches its neighbours' math.  Every non-scalar cache leaf carries batch
# at axis 1 (see ``cache_specs``); the seq-capacity leaves below are the
# only ones needing cursor alignment — everything else is per-row O(1)
# state copied wholesale.

SEQ_CACHE_KEYS = ("k", "v", "k_scale", "v_scale")


def arena_supported(cfg: ModelConfig) -> bool:
    """Families whose caches support slot insert/free (all token-prompt LM
    families; encdec needs frames and modality stubs stay wave-only)."""
    return cfg.family in ("dense", "moe", "vlm", "hybrid", "ssm") \
        and not cfg.embeds_input


def arena_init_cache(cfg: ModelConfig, batch: int, cap: int, cursor: int):
    """A fresh arena: capacity ``cap``, write cursor ``cursor``, every row
    fully masked (``start == cursor``) until something is inserted."""
    model = build_model(cfg)
    if cfg.family == "ssm":
        return model.init_cache(batch, cap, filled=cursor)
    return model.init_cache(batch, cap, filled=cursor,
                            start=jnp.full((batch,), cursor, jnp.int32))


def cache_extract_rows(cfg: ModelConfig, cache, rows):
    """Row-subset of a cache pytree (batch axis 1 everywhere; per-row
    ``start`` subset; scalar ``idx`` kept) — the primitive behind prefix-
    cache capture and slot hand-off."""
    rows = jnp.asarray(rows, jnp.int32)
    out = {}
    for key, a in cache.items():
        if key == "idx":
            out[key] = a
        elif key == "start":
            out[key] = a[rows]
        else:
            out[key] = a[:, rows]
    return out


def cache_insert_rows(cfg: ModelConfig, arena, rows, slots, lengths,
                      width: int | None = None, check: bool = True):
    """Insert per-row caches (a prefill result of seq width ``width``) into
    arena slots, aligned so each row's last real token lands at the arena
    cursor minus one; the row's ``start`` becomes ``idx - length`` (its
    left pad and whatever junk precedes it stay masked).  Requires
    ``width <= idx`` — iteration-level schedulers initialise the cursor at
    the prompt-capacity bucket so this always holds.  Jit-compatible with
    ``check=False`` (the cursor bound cannot be asserted on a tracer)."""
    slots = jnp.asarray(slots, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    cur = arena["idx"]
    out = dict(arena)
    if cfg.family == "ssm":
        for key, a in arena.items():
            if key == "idx":
                continue
            out[key] = a.at[:, slots].set(rows[key].astype(a.dtype))
        return out
    if width is None:
        width = int(rows["idx"])
    if check and width > int(cur):
        raise ValueError(
            f"cache_insert_rows: row width {width} exceeds arena cursor "
            f"{int(cur)} — the arena must be initialised with cursor >= "
            "the prompt-capacity bucket")
    pos = cur - width + jnp.arange(width)
    for key, a in arena.items():
        if key == "idx":
            continue
        if key == "start":
            out[key] = a.at[slots].set((cur - lengths).astype(jnp.int32))
            continue
        r = rows[key]
        if key in SEQ_CACHE_KEYS:
            out[key] = a.at[:, slots[:, None], pos[None, :]].set(
                r.astype(a.dtype))
        else:
            out[key] = a.at[:, slots].set(r.astype(a.dtype))
    return out


def cache_insert_rows_masked(cfg: ModelConfig, arena, rows, sel, mask,
                             lengths, width: int):
    """Shape-stable variant of :func:`cache_insert_rows` for jitted
    admission: every arena row is (conditionally) written in one fused op.

    ``rows`` carries a full arena-batch of candidate rows (a ``min_rows``-
    pinned prefill); ``sel (B,)`` names each arena slot's source row,
    ``mask (B,)`` which slots are actually replaced, ``lengths (B,)`` the
    per-slot real token count (ignored where unmasked).  All shapes are
    fixed by ``(B, width)``, so ONE program compiles per prompt-width
    bucket — an index-scattered insert would compile per admission size,
    which is a multi-hundred-ms stall on the serve path.
    """
    sel = jnp.asarray(sel, jnp.int32)
    mask = jnp.asarray(mask, bool)
    lengths = jnp.asarray(lengths, jnp.int32)
    cur = arena["idx"]
    out = dict(arena)
    if cfg.family == "ssm":
        for key, a in arena.items():
            if key == "idx":
                continue
            r = rows[key][:, sel].astype(a.dtype)
            m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
            out[key] = jnp.where(m, r, a)
        return out
    pos = cur - width + jnp.arange(width)
    for key, a in arena.items():
        if key == "idx":
            continue
        if key == "start":
            out[key] = jnp.where(mask, (cur - lengths).astype(jnp.int32),
                                 a).astype(jnp.int32)
            continue
        r = rows[key][:, sel].astype(a.dtype)
        if key in SEQ_CACHE_KEYS:
            window = a[:, :, pos]
            m = mask.reshape((1, -1) + (1,) * (window.ndim - 2))
            out[key] = a.at[:, :, pos].set(jnp.where(m, r, window))
        else:
            m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
            out[key] = jnp.where(m, r, a)
    return out


def cache_free_rows(cfg: ModelConfig, arena, slots):
    """Evict rows: ``start`` jumps to the cursor so a freed slot holds no
    valid keys (its future junk writes stay masked) and stops pinning
    compaction.  O(1)-state families have nothing to mask — a freed row's
    output is simply never read."""
    if "start" not in arena:
        return arena
    slots = jnp.asarray(slots, jnp.int32)
    out = dict(arena)
    out["start"] = arena["start"].at[slots].set(
        jnp.int32(int(arena["idx"])))
    return out


def cache_shift_left(cfg: ModelConfig, arena, shift: int):
    """Compact the arena: roll every seq-capacity leaf left by ``shift``
    (the minimum live ``start``), rebasing ``start``/``idx``.  Wrapped
    junk lands beyond the new cursor, where the decode mask never looks —
    this is what lets a long-running arena's cursor stay bounded."""
    if cfg.family == "ssm" or shift <= 0:
        return arena
    out = dict(arena)
    for key in SEQ_CACHE_KEYS:
        if key in arena:
            out[key] = jnp.roll(arena[key], -shift, axis=2)
    out["start"] = (arena["start"] - shift).astype(jnp.int32)
    out["idx"] = arena["idx"] - jnp.int32(shift)
    return out


# ------------------------------------------------- paged-arena primitives --
# ISSUE 7 generalises the slot arena to a refcounted pool of fixed-size KV
# *blocks* plus a per-row int32 block table: capacity is live tokens, not
# slots × max-len, rows sharing a block-aligned prompt prefix share the
# physical blocks (refcount++), and "compaction" is dropping refcounts —
# no arena rolls.  Block id 0 is reserved as the TRASH block: never
# allocated, pinned at refcount 1, the landing zone for dead-row and
# pad-position writes (always masked out of attention by kv_len).
#
# The device side is just two pool tensors (L, NB, BS, Hkv, D) updated by
# the jitted model fns; everything below is HOST accounting (numpy), kept
# in the worker's state-registry entry next to the pools.

def paged_supported(cfg: ModelConfig) -> bool:
    """Families servable from a paged arena.  Attention families need the
    plain (unquantized) KV pool layout; ssm has O(1) state and is served
    paged via whole-state snapshots at the engine layer (no block pool).
    hybrid keeps per-row conv/ssd state interleaved with KV — it stays on
    the slot arena."""
    if cfg.family == "ssm":
        return True
    return (cfg.family in ("dense", "moe", "vlm")
            and not cfg.embeds_input and cfg.kv_quant != "int8")


def paged_init_pool(cfg: ModelConfig, blocks: int, block_size: int):
    """Zeroed K/V block pools: (L, NB, BS, Hkv, D) in the cache dtype.
    Block 0 is the trash block — part of the tensor, never handed out."""
    cdt = jnp.dtype(cfg.param_dtype)
    shp = (cfg.n_layers, blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, cdt), "v": jnp.zeros(shp, cdt)}


class PagedArena:
    """Host-side block accounting for one worker's paged KV pool.

    Tracks, per physical block, a refcount (rows holding it + the radix
    index holding it each count one reference); per row, the int32 block
    table, resident token count, and liveness.  A block returns to the
    free list only when its refcount hits zero — which is why LRU index
    eviction can never free a block a live row references.
    """

    def __init__(self, batch: int, blocks: int, table_width: int,
                 block_size: int):
        self.batch = int(batch)
        self.nb = int(blocks)
        self.T = int(table_width)
        self.bs = int(block_size)
        self.table = np.zeros((batch, table_width), np.int32)
        self.ref = np.zeros((blocks,), np.int32)
        self.ref[0] = 1                         # pin the trash block
        self.free = list(range(blocks - 1, 0, -1))
        self.len = np.zeros((batch,), np.int32)
        self.live = np.zeros((batch,), bool)
        self.owned: dict[int, list[int]] = {s: [] for s in range(batch)}

    # ---- block lifecycle ----
    def alloc(self) -> int:
        """One fresh block at refcount 1; raises IndexError when exhausted
        (callers relieve pressure by evicting radix-held blocks first)."""
        if not self.free:
            raise IndexError("paged arena: block pool exhausted")
        bid = self.free.pop()
        self.ref[bid] = 1
        return bid

    def ref_inc(self, ids) -> None:
        for bid in ids:
            assert bid != 0 and self.ref[bid] > 0, bid
            self.ref[bid] += 1

    def ref_dec(self, ids) -> list[int]:
        """Drop one reference per id; returns the ids that hit zero (their
        slots are back on the free list — physical contents are stale
        garbage, always masked until overwritten)."""
        freed = []
        for bid in ids:
            assert bid != 0 and self.ref[bid] > 0, bid
            self.ref[bid] -= 1
            if self.ref[bid] == 0:
                self.free.append(bid)
                freed.append(bid)
        return freed

    # ---- row lifecycle ----
    def adopt(self, slot: int, ids, n_tokens: int) -> None:
        """Bind already-referenced blocks (a radix prefix hit, refcounts
        bumped by the caller) as the row's head: table[:len(ids)] = ids."""
        self.table[slot, :len(ids)] = ids
        self.owned[slot].extend(int(i) for i in ids)
        self.len[slot] = n_tokens

    def ensure(self, slot: int, n_tokens: int) -> list[int]:
        """Allocate blocks so the row can hold ``n_tokens`` tokens; returns
        the newly allocated ids (table entries already set)."""
        need = -(-int(n_tokens) // self.bs)     # ceil
        if need > self.T:
            raise ValueError(
                f"paged arena: row needs {need} blocks > table width "
                f"{self.T}")
        new = []
        for bi in range(need):
            if self.table[slot, bi] == 0:
                bid = self.alloc()
                self.table[slot, bi] = bid
                self.owned[slot].append(bid)
                new.append(bid)
        return new

    def release(self, slot: int) -> list[int]:
        """Free a row: drop one reference on every block it holds, clear
        its table row.  Returns the block ids whose refcount hit zero."""
        freed = self.ref_dec(self.owned[slot])
        self.owned[slot] = []
        self.table[slot, :] = 0
        self.len[slot] = 0
        self.live[slot] = False
        return freed

    # ---- observability ----
    def occupancy(self) -> dict:
        allocated = self.nb - 1 - len(self.free)
        shared = int((self.ref[1:] > 1).sum())
        return {"live_tokens": int(self.len[self.live].sum()),
                "allocated_blocks": int(allocated),
                "shared_blocks": shared,
                "free_blocks": len(self.free),
                "total_blocks": self.nb - 1,
                "block_size": self.bs}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract entry-point inputs for one (arch × shape) cell.

    train/prefill -> {"batch": {...}};  decode -> {"cache": ..., "tokens"}.
    Modality frontends are stubs: vlm/audio cells receive precomputed
    patch/frame embeddings (embeds_input), per the assignment.
    """
    b, s = shape.global_batch, shape.seq_len
    cdt = cfg.compute_dtype
    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, s, cfg.d_model), cdt)
            batch["tokens"] = _sds((b, s), "int32")
        elif cfg.embeds_input:
            batch["embeds"] = _sds((b, s, cfg.d_model), cdt)
            if cfg.mrope_sections:
                batch["pos3d"] = _sds((3, b, s), "int32")
        else:
            batch["tokens"] = _sds((b, s), "int32")
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), "int32")
        return {"batch": batch}

    # decode: one new token against a cache of capacity seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {"cache": cache, "tokens": _sds((b, 1), "int32")}
