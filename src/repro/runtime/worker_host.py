"""Worker host — the separately-deployed entry point (paper §3.3, Fig 5).

This is the *server* half of the real transports: a fresh process that
knows nothing about the client except the deployment manifest.  It rebuilds
bridges on demand (thaw the shipped code, AOT-compile against the first
invocation's payload — a genuine cold start), accounts sandboxes with the
same :class:`~repro.runtime.sandbox.SandboxHost` the in-process backends
use, and speaks only the versioned wire protocol
(:mod:`repro.serialization.wire`).

Two front-ends share one :class:`WorkerHost`, both reachable through the
CLI (``python -m repro.runtime.worker_host --manifest m.json``):

* ``stdio_main(...)`` / ``--stdio``  — length-prefixed wire frames on
  stdin/stdout, one subprocess per sandbox slot (``processes`` backend);
* ``serve_http(...)`` / ``--port``   — stdlib ``http.server`` POST /invoke
  endpoint (``http`` backend, the paper's client model); deployable
  standalone anywhere the package tree exists.

Error contract (the wire's, exactly): user-code exceptions become
non-retryable ``ERROR`` envelopes carrying the original traceback text;
anything that escapes the handler is sent as a *retryable* ``ERROR`` (best
effort) before the process dies, so the client surfaces a retryable
invocation error instead of a hung future.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading
import time
import traceback

from ..core.codeship import thaw_function
from ..core.function import RemoteFunction
from ..core.manifest import Manifest, ManifestEntry
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..serialization import (ArtifactMissingError, deserialize,
                             import_artifact_blob, wire)
from .sandbox import SandboxHost

# worker-side request metrics (process-default registry; per-function
# entry accounting lives in the sandbox host's private registry — both are
# merged into the host_stats reply and the /metrics exposition)
_M_REQS = obs_metrics.REGISTRY.counter(
    "worker_requests_total", "INVOKE frames handled")
_M_CTRL = obs_metrics.REGISTRY.counter(
    "worker_control_total", "CONTROL frames handled")
_M_INFLIGHT = obs_metrics.REGISTRY.gauge(
    "worker_inflight", "INVOKE frames currently executing")
_M_EXPIRED = obs_metrics.REGISTRY.counter(
    "worker_deadline_rejections_total",
    "INVOKE frames rejected because their deadline had already passed")
_M_CHAOS = obs_metrics.REGISTRY.counter(
    "chaos_worker_events_total", "chaos CONTROL verbs executed worker-side")
# eagerly registered so every /metrics exposition carries the serving
# histograms' bucket layout even before (or without) the batcher running
# in this process — the client-side batcher observes into the same names,
# and the fleet merge requires exact bucket agreement
obs_metrics.REGISTRY.histogram(
    "serve_ttft_ms", "time to first token (ms)")
obs_metrics.REGISTRY.histogram(
    "serve_tpot_ms", "per-token decode latency (ms)")


class WorkerHost:
    """Manifest-driven bridge cache + wire-protocol request handler."""

    def __init__(self, manifest_path: str, *, worker_id_base: int | None = None):
        self.manifest_path = manifest_path
        self.manifest = Manifest(manifest_path)
        self._bridges: dict[str, object] = {}
        self._build_locks: dict[str, threading.Lock] = {}
        self._lock = threading.Lock()
        base = (os.getpid() % 100_000) * 1_000 \
            if worker_id_base is None else worker_id_base
        self.sandboxes = SandboxHost(worker_id_base=base)

    # ------------------------------------------------------------ bridges
    def _entry_for(self, name: str) -> ManifestEntry:
        if name not in self.manifest.entries:
            try:
                # the client deploys continuously; reload before giving up
                self.manifest.load(self.manifest_path)
            except OSError:
                pass                   # nothing deployed yet
        try:
            return self.manifest.get(name)
        except KeyError:
            raise LookupError(
                f"function {name!r} not in manifest {self.manifest_path!r}"
            ) from None

    def _build_bridge(self, entry: ManifestEntry, example_payload: bytes):
        """Rebuild a bridge from the manifest — the worker-side deploy.

        AOT specialization needs example arguments; the first invocation's
        payload provides them (and pays the compile, i.e. the cold start).
        """
        from ..core.bridge import (Bridge, make_executor_aot,
                                   make_executor_generic)
        fn = thaw_function(entry.code)
        rf = RemoteFunction(fn, name=entry.human_name, config=entry.config,
                            jax_traceable=(entry.kind == "aot_xla"))
        args, kwargs, captures = deserialize(example_payload)
        kind = "generic_worker"
        if rf.jax_traceable:
            try:
                executor = make_executor_aot(rf, args, kwargs, captures)
                kind = "aot_xla"
            except Exception:
                executor = make_executor_generic(rf)
        else:
            executor = make_executor_generic(rf)
        return Bridge(name=entry.name, config=entry.config,
                      executor=executor, kind=kind)

    def get_bridge(self, name: str, example_payload: bytes):
        with self._lock:
            bridge = self._bridges.get(name)
            if bridge is not None:
                return bridge
            build_lock = self._build_locks.setdefault(name, threading.Lock())
        # per-name build lock: concurrent first invocations of one function
        # must not each pay the AOT compile (multi-second for real models)
        with build_lock:
            with self._lock:
                bridge = self._bridges.get(name)
                if bridge is not None:
                    return bridge
            entry = self._entry_for(name)
            bridge = self._build_bridge(entry, example_payload)
            with self._lock:
                self._bridges[name] = bridge
            return bridge

    # ------------------------------------------------------------ handler
    def handle(self, data: bytes) -> bytes:
        """One request → one reply, both wire frames.  Never raises on user
        or protocol errors — those become ``ERROR`` envelopes; only a host
        bug escapes (and the transport loops turn it into a retryable
        error before dying)."""
        t_recv = time.time()
        t0 = time.perf_counter()
        try:
            msg = wire.decode(data)
        except wire.WireProtocolError as e:
            return wire.encode_error(e, retryable=False)
        if isinstance(msg, wire.ControlRequest):
            _M_CTRL.inc(op=msg.op)
            return self._handle_control(msg)
        if not isinstance(msg, wire.InvokeRequest):
            return wire.encode_error(
                etype="WireProtocolError", retryable=False,
                message=f"unexpected frame {type(msg).__name__} on a worker")
        # deadline propagation (ISSUE 10): already-expired work is rejected
        # BEFORE any bridge build or entry call — the worker does not burn
        # compute on a result no client is waiting for.  Non-retryable by
        # design (a retry cannot un-expire it); TimeoutError is a builtin,
        # so the client reconstructs the exact type.
        if msg.deadline is not None and t_recv > msg.deadline:
            _M_EXPIRED.inc(function=msg.function)
            return wire.encode_error(
                etype="TimeoutError", retryable=False,
                message=(f"deadline exceeded before execution: task "
                         f"{msg.task_id} arrived {t_recv - msg.deadline:.3f}s "
                         "past its deadline"))
        # worker-side spans exist only when the client sampled this request
        # (the trace header field IS the sampling decision crossing the
        # wire); they ship back on the reply envelope — the worker keeps
        # nothing and needs no tracing config of its own
        spans = obs_trace.RemoteSpans(msg.trace)
        if spans:
            spans.span_at("worker.decode", t_recv,
                          time.perf_counter() - t0, bytes=len(data))
        _M_REQS.inc(function=msg.function)
        _M_INFLIGHT.inc()
        try:
            with self._lock:
                first_use = msg.function not in self._bridges
            cspan = (spans.span("worker.compile", function=msg.function)
                     if first_use else obs_trace.NOOP)
            with cspan:
                bridge = self.get_bridge(msg.function, msg.payload)
            with spans.span("worker.entry", function=msg.function) as espan:
                done = self.sandboxes.invoke(
                    bridge.entry, msg.function, msg.payload,
                    task_id=msg.task_id, attempt=msg.attempt)
                espan.set("cold_start", done.cold_start)
                espan.set("worker_id", done.worker_id)
        except ArtifactMissingError as e:  # no shared fs: ask for a push
            return wire.encode_artifact_missing(e.sha, e.path)
        except Exception as e:             # user code / lookup / deserialize
            return wire.encode_error(
                e, traceback_text=traceback.format_exc(), retryable=False,
                spans=spans.dicts() or None)
        finally:
            _M_INFLIGHT.dec()
        s = done.stats
        return wire.encode_result(
            done.blob,
            stats={"deserialize_s": s.deserialize_s, "compute_s": s.compute_s,
                   "serialize_s": s.serialize_s},
            server_s=done.server_s, cold_start=done.cold_start,
            worker_id=done.worker_id, spans=spans.dicts() or None)

    def _handle_control(self, msg: wire.ControlRequest) -> bytes:
        if msg.op == "ping":
            return wire.encode_control("pong", pid=os.getpid(),
                                       functions=len(self._bridges))
        if msg.op == "drain":
            name = msg.data.get("function")
            with self._lock:
                if name is None:
                    self._bridges.clear()
                else:
                    self._bridges.pop(name, None)
            return wire.encode_control("drained",
                                       count=self.sandboxes.drain(name))
        if msg.op in ("state_lease", "state_renew", "state_release",
                      "state_stats"):
            # worker-resident serving state (ISSUE 5): lease renewal and
            # release for cache arenas, TTL-reclaimed so a dead client
            # cannot pin worker memory
            from . import state
            try:
                return wire.encode_control(msg.op, **state.control(
                    msg.op, msg.data))
            except Exception as e:
                return wire.encode_error(e, retryable=False)
        if msg.op in ("state_extract_rows", "state_insert_rows"):
            # arena row migration (ISSUE 6): ship finished prefill rows out
            # of / into this worker's resident arenas as CONTROL bodies —
            # the disaggregated prefill→decode hand-off.  Lazy engine
            # import: only workers already running engine entry points
            # (jax loaded) ever receive these.
            from .engine import migration_control
            try:
                reply, body = migration_control(msg.op, msg.data, msg.body)
                return wire.encode_control(msg.op, body=body, **reply)
            except Exception as e:
                return wire.encode_error(e, retryable=False)
        if msg.op == "host_stats":
            # fleet observability (ISSUE 6): this worker's cold/warm and
            # busy-time accounting plus its resident-state leases, one
            # round-trip — what Session.stats() aggregates across slots.
            # ``metrics`` (ISSUE 8) is the uniform registry snapshot the
            # client merges fleet-wide.
            from . import state
            return wire.encode_control(
                "host_stats", pid=os.getpid(), functions=len(self._bridges),
                sandboxes=self.sandboxes.stats(), state=state.stats(),
                metrics=self.metrics_snapshot())
        if msg.op == "chaos":
            # worker-side chaos execution (ISSUE 10): the client's ChaosPlan
            # reaches across the process boundary through this verb —
            # ``expire_leases`` backdates every resident state lease (the
            # next engine call surfaces state-lost), ``stall`` wedges this
            # worker for a bit (straggler), ``die`` hard-exits without a
            # reply (the SIGKILL analogue for transports that cannot signal
            # the process directly, e.g. an external url= http worker).
            from . import state
            action = msg.data.get("action")
            _M_CHAOS.inc(action=str(action))
            if action == "expire_leases":
                expired = state.expire_all(msg.data.get("handles"))
                return wire.encode_control("chaos", ok=True, expired=expired)
            if action == "stall":
                time.sleep(float(msg.data.get("stall_s", 0.0)))
                return wire.encode_control("chaos", ok=True)
            if action == "die":
                os._exit(int(msg.data.get("code", 9)))
            return wire.encode_error(
                etype="ValueError", retryable=False,
                message=f"unknown chaos action {action!r}")
        if msg.op == "artifact_put":
            # remote artifact fetch: the client pushes a blob this worker
            # reported missing; deposit it in the local store and ack
            try:
                path = import_artifact_blob(msg.data["sha"], msg.body)
                return wire.encode_control("artifact_put", ok=True,
                                           path=path)
            except Exception as e:
                return wire.encode_error(e, retryable=False)
        return wire.encode_error(etype="WireProtocolError", retryable=False,
                                 message=f"unknown control op {msg.op!r}")

    def metrics_snapshot(self) -> dict:
        """This worker's full metrics view: the process-default registry
        (request/control counters) merged with the sandbox host's private
        registry (per-function cold/warm/busy) — what rides ``host_stats``
        and backs the http front-end's ``GET /metrics``."""
        merged = obs_metrics.Registry()
        merged.merge(obs_metrics.REGISTRY.snapshot())
        merged.merge(self.sandboxes.metrics.snapshot())
        return merged.snapshot()


# ------------------------------------------------------ processes front-end

def stdio_main(manifest_path: str, worker_id_base: int | None = None) -> None:
    """Framed-stdio loop for one ``processes``-backend worker subprocess.

    Frames are ``u32 length | wire envelope`` on stdin/stdout — the same
    envelopes as HTTP bodies, just a different byte carrier.  BaseExceptions
    that escape the handler (host bug, SystemExit from user code) are
    reported as *retryable* errors with the original traceback — then the
    process exits and the client-side transport respawns a replacement.  A
    hard death (``os._exit``, SIGKILL) sends nothing; the client sees EOF
    and synthesizes the retryable error from the exit code and stderr tail.
    """
    import struct

    inp = sys.stdin.buffer
    out = sys.stdout.buffer
    sys.stdout = sys.stderr        # stray prints must not corrupt framing

    def send(reply: bytes) -> None:
        out.write(struct.pack("<I", len(reply)))
        out.write(reply)
        out.flush()

    host = WorkerHost(manifest_path, worker_id_base=worker_id_base)
    while True:
        header = inp.read(4)
        if len(header) < 4:
            return                 # client closed the pipe: clean shutdown
        (n,) = struct.unpack("<I", header)
        data = inp.read(n)
        if len(data) < n:
            return
        try:
            reply = host.handle(data)
        except BaseException:
            try:
                send(wire.encode_error(
                    etype="WorkerCrash", retryable=True,
                    message="worker died mid-request",
                    traceback_text=traceback.format_exc()))
            except Exception:
                pass
            raise
        try:
            send(reply)
        except (BrokenPipeError, OSError):
            return


# ------------------------------------------------------------ http front-end

READY_MARKER = "WORKER_HOST_READY"


def serve_http(manifest_path: str, *, host: str = "127.0.0.1", port: int = 0,
               announce=None):
    """Serve the wire protocol over stdlib HTTP (POST /invoke).

    Returns the live ``ThreadingHTTPServer`` (caller drives
    ``serve_forever``); ``announce(port)`` fires once the socket is bound —
    the CLI prints the ready line from it so a parent process can scrape
    the chosen port.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    worker = WorkerHost(manifest_path)

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"      # keep-alive: the pooled client

        def do_POST(self):                 # noqa: N802 (stdlib casing)
            n = int(self.headers.get("Content-Length", 0))
            body = self.rfile.read(n)
            try:
                reply = worker.handle(body)
            except BaseException:
                reply = wire.encode_error(
                    etype="WorkerCrash", retryable=True,
                    message="worker died mid-request",
                    traceback_text=traceback.format_exc())
            self.send_response(200)
            self.send_header("Content-Type", "application/octet-stream")
            self.send_header("Content-Length", str(len(reply)))
            self.end_headers()
            self.wfile.write(reply)

        def do_GET(self):                  # noqa: N802 (stdlib casing)
            # Prometheus scrape endpoint — text exposition of this worker's
            # merged metrics (request counters + per-function sandbox
            # accounting).  Anything else is 404.
            if self.path.split("?", 1)[0] != "/metrics":
                self.send_response(404)
                self.send_header("Content-Length", "0")
                self.end_headers()
                return
            text = obs_metrics.render_snapshot(
                worker.metrics_snapshot()).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(text)))
            self.end_headers()
            self.wfile.write(text)

        def log_message(self, *a):         # quiet: latency is measured, not logged
            pass

    class Server(ThreadingHTTPServer):
        daemon_threads = True              # a hung handler never pins exit

    server = Server((host, port), Handler)
    server.worker = worker                 # introspection for in-test workers
    if announce is not None:
        announce(server.server_address[1])
    return server


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Serverless worker host: serve a deployment manifest "
                    "over the wire protocol (framed stdio or HTTP).")
    ap.add_argument("--manifest", required=True)
    ap.add_argument("--stdio", action="store_true",
                    help="speak length-prefixed wire frames on stdin/stdout "
                         "(the `processes` transport)")
    ap.add_argument("--worker-id-base", type=int, default=None)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (announced on stdout)")
    args = ap.parse_args(argv)

    if args.stdio:
        stdio_main(args.manifest, args.worker_id_base)
        return

    def announce(port: int) -> None:
        print(f"{READY_MARKER} port={port}", flush=True)

    server = serve_http(args.manifest, host=args.host, port=args.port,
                        announce=announce)
    # After the READY line stdout belongs to the parent's scraper, which
    # stops reading: user-code prints must go to stderr or they would fill
    # the unread pipe and wedge every handler thread mid-request.
    sys.stdout = sys.stderr
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()


if __name__ == "__main__":
    main()
