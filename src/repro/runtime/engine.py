"""Iteration-level serving engine: worker-resident KV arena + step decode.

PR 4's serving path is batch-level: one deployed entry point runs prefill
*and* the whole decode scan, so a request can only join between batches
and every admission re-runs prefill from scratch.  This module splits
that monolith into the two entry points the paper's warm-state economics
actually want (ISSUE 5):

* :func:`engine_prefill` — prefill arriving prompts in a bucketed side
  buffer and *insert* each row into a worker-resident, slot-allocated
  cache arena (:mod:`repro.runtime.state`), keyed by a client-generated
  handle.  The cache never crosses the wire back; only the first decoded
  token per row returns.  Rows whose full prompt is already resident in
  the arena's prefix store skip prefill compute entirely.
* :func:`engine_decode` — advance *all* live slots ``k`` greedy steps and
  return just the ``(B, k)`` new token ids (a few hundred bytes), freeing
  evicted rows and compacting the arena when the cursor nears capacity.

Both are ordinary shippable functions (``jax_traceable=False``): the
worker imports this module, rebuilds the model from ``cfg`` and pays each
jit once per shape bucket — the same cold-start contract as every other
deployed entry point.  :class:`EngineClient` is the client half: it owns
the handle, mirrors the cursor and the prefix-store LRU (the client is
the single writer, so the mirror is exact), pins every call to one worker
via ``FunctionConfig.affinity`` on cross-process backends, and falls back
to direct :mod:`repro.runtime.state` calls when the backend shares the
client process.
"""
from __future__ import annotations

import hashlib
import itertools
import threading
import uuid
from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import build_model, transformer
from ..models.api import (PagedArena, SEQ_CACHE_KEYS, _attn_impl,
                          arena_init_cache, arena_supported,
                          cache_extract_rows, cache_free_rows,
                          cache_insert_rows, cache_insert_rows_masked,
                          cache_shift_left, paged_init_pool, paged_supported)
from ..serialization import decode_binary, encode_binary
from . import state
from .radix import RadixIndex
from .server import pack_prompts, shape_bucket

DEFAULT_QUANTUM = 8

# CONTROL verbs for arena row migration (disaggregated prefill/decode,
# ISSUE 6): a prefill worker's finished rows ship to a decode worker's
# arena as a binary-archive CONTROL body, client-relayed (the client is
# the single writer of both arenas, so its mirrors stay exact).
MIGRATE_EXTRACT_OP = "state_extract_rows"
MIGRATE_INSERT_OP = "state_insert_rows"


# ---------------------------------------------------------------- hashing --

def prefix_key(tokens: Sequence[int]) -> str:
    """Content hash of a token prefix: length-prefixed over the *raw*
    token ids, never over a padded row.  A prompt that happens to contain
    the pad id therefore cannot collide with a shorter prompt whose
    padded row looks identical (``[pad, x, y]`` vs ``[x, y]``)."""
    h = hashlib.sha256()
    h.update(len(tokens).to_bytes(8, "little"))
    h.update(np.asarray(tokens, np.int64).tobytes())
    return h.hexdigest()


def is_state_lost(err: BaseException) -> bool:
    """The wire-reconstructed signature of a reclaimed/respawned arena."""
    return isinstance(err, KeyError) and "state handle" in str(err)


# ------------------------------------------------------- worker-side jits --

@lru_cache(maxsize=None)
def _model_for(cfg: ModelConfig):
    return build_model(cfg)


@lru_cache(maxsize=64)
def _prefill_fn(cfg: ModelConfig):
    model = _model_for(cfg)

    def run(params, tokens, lengths):
        logits, cache = model.prefill(params, {"tokens": tokens,
                                               "lengths": lengths})
        first = jnp.argmax(logits, -1).astype(jnp.int32)
        return first, cache

    return jax.jit(run)


@lru_cache(maxsize=256)
def _insert_full_fn(cfg: ModelConfig, width: int):
    """Jitted full-batch masked insert + first-token splice: one compiled
    program per prompt-width bucket, whatever subset of slots admits."""
    def run(arena, last, rows, first, sel, mask, lengths):
        arena = cache_insert_rows_masked(cfg, arena, rows, sel, mask,
                                         lengths, width=width)
        last = jnp.where(mask, first[sel], last).astype(jnp.int32)
        return arena, last

    return jax.jit(run)


@lru_cache(maxsize=256)
def _insert_one_fn(cfg: ModelConfig, width: int):
    """Jitted single-row insert (prefix-cache hits re-insert one stored
    row at a time; shapes fixed by width, so this compiles once each)."""
    def run(arena, last, row, slot, length, first_tok):
        arena = cache_insert_rows(cfg, arena, row, slot, length,
                                  width=width, check=False)
        last = last.at[slot[0]].set(jnp.int32(first_tok))
        return arena, last

    return jax.jit(run)


@lru_cache(maxsize=256)
def _decode_fn(cfg: ModelConfig, k: int):
    model = _model_for(cfg)

    def run(params, cache, tok, free_mask):
        # eviction fused into the step program: freed rows jump their
        # ``start`` to the cursor (no valid keys — junk writes stay
        # masked) and feed the pad id.  A (B,) bool mask keeps the
        # compiled program shared across every eviction pattern, where an
        # eager per-slot update would copy the whole arena per chunk.
        if "start" in cache:
            cache = dict(cache)
            cache["start"] = jnp.where(free_mask,
                                       jnp.int32(cache["idx"]),
                                       cache["start"]).astype(jnp.int32)
        tok = jnp.where(free_mask[:, None], jnp.int32(cfg.pad_id), tok)

        def step(carry, _):
            cache, tok = carry
            logits, cache = model.decode(params, cache, tok)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return (cache, nxt), nxt[:, 0]

        (cache, tok), toks = jax.lax.scan(step, (cache, tok), None, length=k)
        return cache, tok[:, 0], jnp.moveaxis(toks, 0, 1)   # (B, k)

    return jax.jit(run)


# ------------------------------------------------------ worker entry fns --

def engine_prefill(params, tokens, lengths, *, cfg, handle, batch, cap,
                   cursor0, miss_slots=(), store_keys=(), hit_slots=(),
                   hit_keys=(), evict_keys=(), create=True,
                   ttl_s=state.DEFAULT_TTL_S):
    """Prefill + slot-insert entry point (worker side).

    ``tokens``/``lengths`` carry the prefix-cache *misses* packed by the
    client (``None`` when every row hit); ``miss_slots`` names the arena
    slot per packed row (filler rows beyond it are discarded).
    ``store_keys`` (parallel to ``miss_slots``) asks the worker to retain
    a row's fresh cache in the arena's prefix store; ``hit_slots`` /
    ``hit_keys`` are rows served straight from it; ``evict_keys`` applies
    the client's LRU decisions.  Returns ``{"first": first token per
    inserted row (miss order then hit order), "idx": cursor}`` — the
    cache itself stays resident and is never serialized back.
    """
    def make():
        return {"cache": arena_init_cache(cfg, batch, cap, cursor0),
                "last": jnp.full((batch,), cfg.pad_id, jnp.int32),
                "prefix": {}, "prefix_tokens": 0, "cap": cap,
                "cursor0": cursor0, "cfg": cfg}

    # ``create`` distinguishes building a fresh arena from renewing one
    # that must already exist: an admission into an arena holding live
    # rows must NOT silently recreate an expired lease (the live rows
    # would decode garbage against a blank cache) — it must surface the
    # state-lost KeyError so the scheduler fails those rows and rebuilds.
    a = state.lease(handle, ttl_s=float(ttl_s),
                    make=make if create else None)
    cache, last = a["cache"], a["last"]
    for key in evict_keys:
        ent = a["prefix"].pop(key, None)
        if ent is not None:
            a["prefix_tokens"] -= ent[1]

    first_out: list[int] = []
    if len(miss_slots):
        n = len(miss_slots)
        if int(cache["idx"]) < int(tokens.shape[1]) \
                and cfg.family != "ssm":
            raise ValueError(
                f"prefill width {int(tokens.shape[1])} exceeds arena "
                f"cursor {int(cache['idx'])}")
        tokens = jnp.asarray(tokens)
        lengths = np.asarray(lengths, np.int32)
        width = int(tokens.shape[1])
        first, pcache = _prefill_fn(cfg)(params, tokens,
                                         jnp.asarray(lengths))
        first = np.asarray(first)
        for j, key in enumerate(store_keys):
            if key is None or key in a["prefix"]:
                continue
            row = cache_extract_rows(cfg, pcache, (j,))
            a["prefix"][key] = (row, int(lengths[j]), int(first[j]), width)
            a["prefix_tokens"] += int(lengths[j])
        # shape-stable masked insert: sel routes packed row j to its slot
        rows_b = last.shape[0]
        sel = np.zeros((rows_b,), np.int32)
        mask = np.zeros((rows_b,), bool)
        len_by_slot = np.zeros((rows_b,), np.int32)
        for j, slot in enumerate(miss_slots):
            sel[slot], mask[slot] = j, True
            len_by_slot[slot] = lengths[j]
        if first.shape[0] < rows_b:
            raise RuntimeError("prefill batch smaller than the arena: "
                               "pack with min_rows == arena rows")
        cache, last = _insert_full_fn(cfg, width)(
            cache, last, pcache, jnp.asarray(first),
            jnp.asarray(sel), jnp.asarray(mask), jnp.asarray(len_by_slot))
        first_out.extend(int(t) for t in first[:n])

    for slot, key in zip(hit_slots, hit_keys):
        ent = a["prefix"].get(key)
        if ent is None:
            raise KeyError(
                f"prefix key {key[:12]}… not resident for state handle "
                f"{handle!r} (stale client mirror)")
        row, length, t0, width = ent
        cache, last = _insert_one_fn(cfg, width)(
            cache, last, row, jnp.asarray([slot], jnp.int32),
            jnp.asarray([length], jnp.int32), t0)
        first_out.append(t0)

    a["cache"], a["last"] = cache, last
    return {"first": np.asarray(first_out, np.int32),
            "idx": int(cache["idx"])}


def engine_decode(params, *, cfg, handle, k, free_slots=(),
                  ttl_s=state.DEFAULT_TTL_S):
    """Decode-step entry point (worker side): free evicted rows, compact
    if the cursor nears capacity, advance every slot ``k`` greedy steps.
    Returns ``{"tokens": (B, k) ids, "idx": post-step cursor}``."""
    a = state.get(handle, ttl_s=float(ttl_s))
    cache, last = a["cache"], a["last"]
    k = int(k)
    free_mask = np.zeros((last.shape[0],), bool)
    if len(free_slots):
        free_mask[np.asarray(free_slots, np.int64)] = True
    if cfg.family != "ssm":
        cap = a["cap"]
        if int(cache["idx"]) + k >= cap:
            # compaction bound: minimum start over rows that are NOT being
            # freed this call (schedulers pass every non-live slot in
            # free_slots each chunk, so idle freed slots cannot pin the
            # shift at their freeze-time start).  Clamped so the cursor
            # never drops below the prompt-width bucket — otherwise the
            # next admission's insert would have no room to align against.
            starts = np.asarray(cache["start"])
            starts = np.where(free_mask, int(cache["idx"]), starts)
            shift = min(int(starts.min()),
                        int(cache["idx"]) - int(a.get("cursor0", 0)))
            cache = cache_shift_left(cfg, cache, shift)
            if int(cache["idx"]) + k >= cap:
                raise RuntimeError(
                    f"cache arena {handle!r} full: cursor "
                    f"{int(cache['idx'])} + {k} exceeds capacity {cap} "
                    "even after compaction (a live row spans the arena)")
    cache, last, toks = _decode_fn(cfg, k)(params, cache, last[:, None],
                                           jnp.asarray(free_mask))
    a["cache"], a["last"] = cache, last
    return {"tokens": np.asarray(toks), "idx": int(cache["idx"])}


# ------------------------------------------------- paged-arena entry fns --
# ISSUE 7: the paged twin of the slot entry points above.  The worker
# keeps a refcounted pool of fixed-size KV blocks plus per-row block
# tables (host accounting in models.api.PagedArena, device pools updated
# by the jitted fns below); prefill is CHUNKED — each call advances
# pending rows by at most ``budget`` real tokens, so a long prompt never
# stalls live decode rows for more than one chunk — and the prompt-prefix
# store is a radix index over block-aligned token runs: rows sharing a
# prefix share physical blocks copy-free, and a partial hit skips prefill
# for the matched head only.

@lru_cache(maxsize=256)
def _paged_chunk_fn(cfg: ModelConfig, c: int):
    """One chunk of continued prefill for one row (B == 1, width c)."""
    impl = _attn_impl(cfg)

    def run(params, pool_k, pool_v, tokens, table, m, n_real):
        logits, pk, pv = transformer.lm_prefill_paged_chunk(
            params, cfg, tokens, pool_k, pool_v, table, m, n_real,
            attn_impl=impl)
        first = jnp.argmax(logits, -1).astype(jnp.int32)
        return first, pk, pv

    return jax.jit(run)


@lru_cache(maxsize=256)
def _paged_decode_fn(cfg: ModelConfig, k: int):
    impl = _attn_impl(cfg)

    def run(params, pool_k, pool_v, table, lens, live, last):
        tok = jnp.where(live, last, jnp.int32(cfg.pad_id))[:, None]

        def step(carry, _):
            pk, pv, lens, tok = carry
            logits, pk, pv = transformer.lm_decode_paged(
                params, cfg, pk, pv, table, lens, live, tok, attn_impl=impl)
            nxt = jnp.where(live, jnp.argmax(logits, -1).astype(jnp.int32),
                            jnp.int32(cfg.pad_id))
            lens = lens + live.astype(jnp.int32)
            return (pk, pv, lens, nxt[:, None]), nxt

        (pk, pv, lens, tok), toks = jax.lax.scan(
            step, (pool_k, pool_v, lens, tok), None, length=k)
        return pk, pv, tok[:, 0], jnp.moveaxis(toks, 0, 1)       # (B, k)

    return jax.jit(run)


def _paged_reserve(pa: PagedArena, radix: RadixIndex, slot: int,
                   n_tokens: int, handle) -> None:
    """Allocate blocks so row ``slot`` can hold ``n_tokens``; on pool
    exhaustion, evict LRU radix runs (refcount drop — blocks free only if
    no live row shares them) and retry before giving up."""
    while True:
        try:
            pa.ensure(slot, n_tokens)
            return
        except IndexError:
            dropped = radix.evict_blocks(1)
            if not dropped:
                raise RuntimeError(
                    f"paged arena {handle!r} out of blocks: "
                    f"{pa.occupancy()} and nothing evictable") from None
            pa.ref_dec(dropped)


def _paged_match(pa: PagedArena, radix: RadixIndex, slot: int,
                 toks: list, done: int) -> int:
    """Adopt any radix-shared prefix blocks beyond ``done`` (refcount++,
    copy-free).  The match is capped one block short of the full prompt so
    at least one token always re-prefills — the chunk path needs a real
    last-token forward for the first output logits."""
    bs = pa.bs
    if done % bs:
        return done
    h, payloads = radix.match(toks)
    h = min(h, ((len(toks) - 1) // bs) * bs)
    if h <= done:
        return done
    ids = payloads[done // bs:h // bs]
    if any(pa.table[slot, done // bs:h // bs]):
        return done                      # row already allocated past here
    pa.ref_inc(ids)
    pa.table[slot, done // bs:h // bs] = ids
    pa.owned[slot].extend(int(i) for i in ids)
    return h


def engine_paged_prefill(params, *, cfg, handle, batch, blocks, table_width,
                         block_size, admit=(), free=(), budget=0,
                         radix_tokens=1 << 16, create=True,
                         ttl_s=state.DEFAULT_TTL_S):
    """Paged prefill entry point: admit rows, advance chunked prefill.

    ``free``: slots evicted since the last call — released FIRST (refcount
    drops), because a slot must give its blocks back before the same slot
    id is re-admitted: an un-released table row would alias the new row's
    writes onto blocks the radix index may still share with live rows.
    ``admit``: ``[(slot, prompt_tokens), ...]`` new rows (the worker is
    authoritative for prefix matching — no client mirror).  Each call then
    advances pending rows FIFO by at most ``budget`` real tokens total
    (``budget <= 0`` = finish everything), so one call's prefill stall is
    bounded no matter how long the prompt.  Completed rows land live with
    their first decoded token; their full blocks are inserted into the
    radix index (refcount++) and the index is LRU-evicted back under
    ``radix_tokens``.  Returns per-slot progress + pool occupancy.
    """
    def make():
        pool = paged_init_pool(cfg, blocks, block_size)
        return {"paged": True, "cfg": cfg,
                "pool_k": pool["k"], "pool_v": pool["v"],
                "pa": PagedArena(batch, blocks, table_width, block_size),
                "radix": RadixIndex(block_size, radix_tokens),
                "pending": {}, "order": [],
                "last": np.full((batch,), cfg.pad_id, np.int32),
                "prefix_tokens": 0}

    a = state.lease(handle, ttl_s=float(ttl_s),
                    make=make if create else None)
    pa, radix = a["pa"], a["radix"]
    pending, order = a["pending"], a["order"]

    for slot in free:
        slot = int(slot)
        pa.ref_dec(radix.evict())
        pa.release(slot)
        pending.pop(slot, None)

    for slot, toks in admit:
        slot = int(slot)
        toks = [int(t) for t in toks]
        matched = _paged_match(pa, radix, slot, toks, 0)
        pending[slot] = {"tokens": toks, "done": matched, "matched": matched}
        order.append(slot)

    spent = 0
    out: dict[int, dict] = {}
    while order:
        slot = order[0]
        ent = pending.get(slot)
        if ent is None:                       # freed mid-prefill
            order.pop(0)
            continue
        toks, done = ent["tokens"], ent["done"]
        done = _paged_match(pa, radix, slot, toks, done)
        need = len(toks) - done
        room = (len(toks) if budget <= 0
                else budget - spent)
        c_real = min(need, room)
        if c_real <= 0:
            break                             # budget exhausted this call
        _paged_reserve(pa, radix, slot, done + c_real, handle)
        c_b = shape_bucket(c_real)
        chunk = np.full((1, c_b), cfg.pad_id, np.int32)
        chunk[0, :c_real] = toks[done:done + c_real]
        first, pk, pv = _paged_chunk_fn(cfg, c_b)(
            params, a["pool_k"], a["pool_v"], jnp.asarray(chunk),
            jnp.asarray(pa.table[slot:slot + 1]),
            jnp.int32(done), jnp.int32(c_real))
        a["pool_k"], a["pool_v"] = pk, pv
        done += c_real
        spent += c_real
        ent["done"] = done
        if done == len(toks):
            order.pop(0)
            pending.pop(slot)
            pa.len[slot] = done
            pa.live[slot] = True
            t0 = int(np.asarray(first)[0])
            a["last"][slot] = t0
            nb_full = (done // pa.bs) * pa.bs
            if nb_full and radix_tokens > 0:
                new = radix.insert(toks[:nb_full],
                                   list(pa.table[slot, :nb_full // pa.bs]))
                pa.ref_inc(new)
                pa.ref_dec(radix.evict())
            out[slot] = {"live": True, "first": t0, "done": done,
                         "matched": ent["matched"], "total": done}
        else:
            out[slot] = {"live": False, "first": None, "done": done,
                         "matched": ent["matched"], "total": len(toks)}
    a["prefix_tokens"] = radix.tokens
    occ = pa.occupancy()
    occ["radix_tokens"] = radix.tokens
    a["occupancy"] = occ
    # str slot keys: the wire serializer only carries str-keyed dicts
    return {"slots": {str(s): v for s, v in out.items()},
            "pending": len(pending), "occupancy": occ}


def engine_paged_decode(params, *, cfg, handle, k, free_slots=(),
                        ttl_s=state.DEFAULT_TTL_S):
    """Paged decode-step entry point: release evicted rows (refcount drops
    — the paged analogue of compaction), reserve blocks for ``k`` new
    tokens per live row, advance every live row ``k`` greedy steps."""
    a = state.get(handle, ttl_s=float(ttl_s))
    pa, radix = a["pa"], a["radix"]
    k = int(k)
    for slot in free_slots:
        slot = int(slot)
        pa.ref_dec(radix.evict())            # keep index inside its budget
        pa.release(slot)
        a["pending"].pop(slot, None)
    for slot in np.nonzero(pa.live)[0]:
        _paged_reserve(pa, radix, int(slot), int(pa.len[slot]) + k, handle)
    pk, pv, last, toks = _paged_decode_fn(cfg, k)(
        params, a["pool_k"], a["pool_v"], jnp.asarray(pa.table),
        jnp.asarray(pa.len), jnp.asarray(pa.live), jnp.asarray(a["last"]))
    a["pool_k"], a["pool_v"] = pk, pv
    a["last"] = np.asarray(last).astype(np.int32)
    pa.len[pa.live] += k
    occ = pa.occupancy()
    occ["radix_tokens"] = radix.tokens
    a["occupancy"] = occ
    return {"tokens": np.asarray(toks), "occupancy": occ}


# ------------------------------------------------------- row migration ------

def migration_control(op: str, data: dict, body: bytes = b""):
    """Worker-side CONTROL surface for arena row migration — runs wherever
    the state registry lives (the pinned worker process on cross-process
    backends, the client process otherwise).  Returns ``(reply_data,
    reply_body)``; errors raise (the worker host wraps them in ERROR
    envelopes, so an expired arena surfaces as the usual state-lost
    ``KeyError`` client-side)."""
    if op == MIGRATE_EXTRACT_OP:
        return _migrate_extract(data)
    if op == MIGRATE_INSERT_OP:
        return _migrate_insert(data, body)
    raise ValueError(f"unknown migration op {op!r}")


def _migrate_extract(data: dict):
    """Window-extract rows from a resident arena (and free their slots):
    the prefill half of a prefill→decode hand-off.  The body is a binary
    archive of ``{"rows", "lengths", "last"}`` with the row axis at
    position 1 everywhere (:func:`cache_extract_rows` layout), seq keys
    trimmed to the trailing ``width`` positions so only each row's live
    window crosses the wire."""
    a = state.get(data["handle"],
                  ttl_s=float(data.get("ttl_s") or state.DEFAULT_TTL_S))
    cfg = a["cfg"]
    cache, last = a["cache"], a["last"]
    slots = [int(s) for s in data["slots"]]
    rows = cache_extract_rows(cfg, cache, slots)
    last_np = np.asarray(last)[slots].astype(np.int64)
    if cfg.family == "ssm":
        width = 0                        # O(1) state: whole-row, no window
        lengths = np.asarray(data.get("lengths", [0] * len(slots)), np.int64)
        payload = {k: np.asarray(v) for k, v in rows.items()
                   if k not in ("idx", "start")}
    else:
        idx = int(cache["idx"])
        width = int(data.get("width") or idx)
        lengths = (idx - np.asarray(cache["start"])[slots]).astype(np.int64)
        if width > idx or (len(lengths) and int(lengths.max()) > width):
            raise ValueError(
                f"migration window {width} cannot carry rows of lengths "
                f"{lengths.tolist()} from an arena at cursor {idx}")
        payload = {}
        for k, v in rows.items():
            if k in ("idx", "start"):
                continue
            v = np.asarray(v)
            payload[k] = v[:, :, idx - width:idx] \
                if k in SEQ_CACHE_KEYS else v
        if bool(data.get("free", True)):
            a["cache"] = cache_free_rows(cfg, cache, slots)
    body = encode_binary({"rows": payload, "lengths": lengths,
                          "last": last_np})
    return ({"ok": True, "width": width,
             "lengths": [int(x) for x in lengths],
             "last": [int(x) for x in last_np]}, body)


def _migrate_insert(data: dict, body: bytes):
    """Insert migrated rows into a resident arena: the decode half.  The
    target arena's cursor must already sit at or past the migration width
    (both sides bucket ``prompt_cap`` identically, and decode compaction
    clamps the cursor at ``cursor0``, so this holds by construction)."""
    a = state.get(data["handle"],
                  ttl_s=float(data.get("ttl_s") or state.DEFAULT_TTL_S))
    cfg = a["cfg"]
    cache, last = a["cache"], a["last"]
    blob = decode_binary(body)
    rows = {k: jnp.asarray(np.ascontiguousarray(v))
            for k, v in blob["rows"].items()}
    lengths = np.asarray(blob["lengths"], np.int64)
    last_in = np.asarray(blob["last"], np.int64)
    slots = [int(s) for s in data["slots"]]
    width = int(data.get("width") or 0)
    if cfg.family != "ssm" and width > int(cache["idx"]):
        raise ValueError(
            f"migrated width {width} exceeds arena cursor "
            f"{int(cache['idx'])} for state handle {data['handle']!r}")
    cache = cache_insert_rows(cfg, cache, rows, slots, lengths,
                              width=width, check=False)
    last = last.at[jnp.asarray(slots, jnp.int32)].set(
        jnp.asarray(last_in, jnp.int32))
    a["cache"], a["last"] = cache, last
    return ({"ok": True, "idx": int(cache["idx"])}, b"")


def split_rows(blob: bytes) -> list[dict]:
    """Decode an extraction body into per-row client-side entries, so a
    router can scatter one prefill group across several decode workers.
    Each entry: ``{"rows": {key: (L, 1, ...)}, "length", "last"}``."""
    doc = decode_binary(blob)
    rows, lengths, last = doc["rows"], doc["lengths"], doc["last"]
    n = len(np.asarray(lengths))
    return [{"rows": {k: np.asarray(v)[:, j:j + 1] for k, v in rows.items()},
             "length": int(np.asarray(lengths)[j]),
             "last": int(np.asarray(last)[j])}
            for j in range(n)]


def merge_rows(entries: Sequence[dict]) -> bytes:
    """Concatenate per-row entries (row axis 1) back into one insert body."""
    keys = entries[0]["rows"].keys()
    rows = {k: np.concatenate([e["rows"][k] for e in entries], axis=1)
            for k in keys}
    return encode_binary(
        {"rows": rows,
         "lengths": np.asarray([e["length"] for e in entries], np.int64),
         "last": np.asarray([e["last"] for e in entries], np.int64)})


# ------------------------------------------------------------ client half --

_affinity_counter = itertools.count()


class EngineClient:
    """Client handle for one worker-resident decode arena.

    Owns the state handle, the cursor mirror and the prefix-LRU mirror
    (exact: this client is the arena's only writer), and the bound entry
    points — pinned to one worker via ``affinity`` on cross-process
    backends.  Methods are synchronous and must be driven by a single
    scheduler loop (the iteration-level batcher runs one loop per engine).
    """

    def __init__(self, server, *, rows: int, prompt_cap: int = 64,
                 quantum: int = DEFAULT_QUANTUM, prefix_tokens: int = 1 << 16,
                 ttl_s: float = state.DEFAULT_TTL_S, cap: int | None = None,
                 affinity: int | None = None, paged: bool = False,
                 block_size: int = 16, prefill_budget: int | None = None,
                 pool_blocks: int | None = None):
        cfg = server.cfg
        if not arena_supported(cfg):
            raise ValueError(f"family {cfg.family!r} does not support "
                             "slot-arena serving (wave fallback only)")
        self.server = server
        self.cfg = cfg
        self.rows = int(rows)
        self.quantum = shape_bucket(max(1, quantum))
        self.cursor0 = shape_bucket(max(1, prompt_cap))
        # Paged serving needs the block-pool KV layout; ssm state is O(1)
        # per row (no KV to page) and already admits arbitrary prompt
        # lengths from the slot path, so a paged request degrades to the
        # slot arena there — same contract, nothing to page.
        self.paged = bool(paged) and cfg.family != "ssm" \
            and paged_supported(cfg)
        if self.paged:
            self.block_size = shape_bucket(max(1, block_size))
            # per-row token capacity; MUST stay a power of two — the
            # gathered table view's reduction width is what keeps paged
            # decode bit-identical to the contiguous solo path
            self.cap = shape_bucket(cap) if cap is not None else \
                shape_bucket(4 * max(self.cursor0,
                                     server.max_new + self.quantum))
            self.table_width = self.cap // self.block_size
            self.pool_blocks = (int(pool_blocks) if pool_blocks is not None
                                else 1 + self.rows * self.table_width)
            self.prefill_budget = (int(prefill_budget)
                                   if prefill_budget is not None
                                   else max(4 * self.quantum, 16))
        else:
            self.cap = int(cap) if cap is not None else shape_bucket(
                self.cursor0 + max(4 * self.quantum, 2 * server.max_new))
        self.ttl_s = float(ttl_s)
        self.affinity = (next(_affinity_counter) if affinity is None
                         else int(affinity))
        self.handle = uuid.uuid4().hex
        self.prefix_budget = int(prefix_tokens)
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.occupancy: dict = {}
        self._cursor = self.cursor0
        self._prefix: dict[str, int] = {}       # key -> token count, LRU order
        self._prefix_total = 0
        self._closed = False
        self._hb_thread: threading.Thread | None = None
        self._hb_stop: threading.Event | None = None
        sess = server.session
        self._local_state = not sess.backend.capabilities.cross_process
        common = dict(memory_mb=server._memory_mb, serializer="binary",
                      affinity=self.affinity)
        if self.paged:
            self._f_prefill = sess.function(
                engine_paged_prefill, name=f"engine_paged_prefill_{cfg.name}",
                jax_traceable=False, **common)
            self._f_decode = sess.function(
                engine_paged_decode, name=f"engine_paged_decode_{cfg.name}",
                jax_traceable=False, **common)
        else:
            self._f_prefill = sess.function(
                engine_prefill, name=f"engine_prefill_{cfg.name}",
                jax_traceable=False, **common)
            self._f_decode = sess.function(
                engine_decode, name=f"engine_decode_{cfg.name}",
                jax_traceable=False, **common)

    # ------------------------------------------------------------ sizing --
    def fits(self, prompt_len: int, max_new: int) -> bool:
        """Whether a request can ever live in this arena: its prompt must
        fit below the initial cursor and its whole span (prompt + decode +
        one quantum of slack) below capacity after compaction.  Paged
        arenas have no prompt-cap bound — long prompts chunk-prefill —
        only the per-row table capacity."""
        if self.cfg.family == "ssm":
            return True                      # O(1) state: no capacity bound
        if self.paged:
            return prompt_len + max_new + 2 * self.quantum <= self.cap
        return prompt_len <= self.cursor0 and \
            self.cursor0 + max_new + 2 * self.quantum <= self.cap

    @property
    def cursor(self) -> int:
        return self._cursor

    # ----------------------------------------------------------- prefix --
    def _prefix_plan(self, prompts):
        """Split an admission group into prefix hits and misses, and emit
        the store/evict commands that keep the worker's store equal to the
        client's LRU mirror (LRU by token count, budget ``prefix_tokens``).

        A key stored *and* LRU-evicted within the same plan is cancelled
        out client-side (store slot nulled, no evict emitted): the worker
        applies evicts before stores, so emitting both would leak the
        entry past the budget forever (the mirror forgets a key the
        worker still holds)."""
        hits, misses, store, evict = [], [], [], []
        added_at: dict[str, int] = {}        # keys stored by THIS plan
        for i, p in enumerate(prompts):
            key = prefix_key(p)
            if key in self._prefix:
                self._prefix[key] = self._prefix.pop(key)   # LRU touch
                hits.append((i, key))
                continue
            misses.append(i)
            if self.prefix_budget and len(p) <= self.prefix_budget:
                while self._prefix and \
                        self._prefix_total + len(p) > self.prefix_budget:
                    old, n = next(iter(self._prefix.items()))
                    del self._prefix[old]
                    self._prefix_total -= n
                    if old in added_at:
                        store[added_at.pop(old)] = None     # never stored
                    else:
                        evict.append(old)
                self._prefix[key] = len(p)
                self._prefix_total += len(p)
                added_at[key] = len(store)
                store.append(key)
            else:
                store.append(None)
        self.prefix_hits += len(hits)
        self.prefix_misses += len(misses)
        return hits, misses, store, evict

    # ------------------------------------------------------------- calls --
    def _params(self):
        ref = self.server._params_ref
        if ref is None or self._closed:
            raise RuntimeError("engine is closed (or its LMServer released "
                               "the params artifact)")
        return ref

    def submit_admit(self, items, create: bool = True, free_slots=()):
        """Pack and dispatch one admission group.

        ``items``: ``[(slot, prompt), ...]``.  Returns ``(future,
        slot_order)`` — the future resolves to the worker reply, with
        first tokens aligned to ``slot_order`` (misses first, then hits).
        ``create=False`` asserts the arena already exists (the scheduler
        has live rows in it): an expired lease then surfaces as state
        lost instead of being silently rebuilt under those rows.

        Paged mode sends the raw prompts (the worker's radix index is
        authoritative for prefix matching — no client mirror) plus the
        slots freed since the last call (``free_slots``, released
        worker-side before any slot is re-admitted); the reply is
        per-slot chunked-prefill progress, folded via
        :meth:`observe_paged_prefill`.  ``free_slots`` is ignored on the
        slot path (idle slots are masked by the decode step instead).
        """
        params = self._params()
        if self.paged:
            admit = tuple((int(s), tuple(int(t) for t in p))
                          for s, p in items)
            fut = self._f_prefill.submit(
                params, cfg=self.cfg, handle=self.handle, batch=self.rows,
                blocks=self.pool_blocks, table_width=self.table_width,
                block_size=self.block_size, admit=admit,
                free=tuple(int(s) for s in free_slots),
                budget=self.prefill_budget,
                radix_tokens=self.prefix_budget, create=bool(create),
                ttl_s=self.ttl_s)
            return fut, [s for s, _ in items]
        slots = [s for s, _ in items]
        prompts = [p for _, p in items]
        hits, misses, store, evict = self._prefix_plan(prompts)
        miss_slots = tuple(slots[i] for i in misses)
        hit_slots = tuple(slots[i] for i, _ in hits)
        hit_keys = tuple(k for _, k in hits)
        if misses:
            # min_rows pins the admission batch's row bucket to the arena
            # size: exactly ONE compiled prefill shape per prompt-width
            # bucket ever exists (same trade the batch-level scheduler
            # makes via submit_wave min_rows) — padded filler compute in
            # exchange for never compiling mid-serve
            tokens, lengths = pack_prompts([prompts[i] for i in misses],
                                           pad=self.cfg.pad_id,
                                           min_rows=self.rows)
            tokens, lengths = jnp.asarray(tokens), jnp.asarray(lengths)
        else:
            tokens = lengths = None
        fut = self._f_prefill.submit(
            params, tokens, lengths, cfg=self.cfg, handle=self.handle,
            batch=self.rows, cap=self.cap, cursor0=self.cursor0,
            miss_slots=miss_slots, store_keys=tuple(store),
            hit_slots=hit_slots, hit_keys=hit_keys,
            evict_keys=tuple(evict), create=bool(create), ttl_s=self.ttl_s)
        return fut, list(miss_slots) + list(hit_slots)

    def submit_prefill_step(self, free_slots=()):
        """Paged only: advance pending chunked prefills by one budget's
        worth of tokens (no new admissions).  Returns the future."""
        return self._f_prefill.submit(
            self._params(), cfg=self.cfg, handle=self.handle,
            batch=self.rows, blocks=self.pool_blocks,
            table_width=self.table_width, block_size=self.block_size,
            admit=(), free=tuple(int(s) for s in free_slots),
            budget=self.prefill_budget,
            radix_tokens=self.prefix_budget, create=False, ttl_s=self.ttl_s)

    def submit_step(self, k: int, free_slots=()):
        """Dispatch one ``k``-step decode chunk (optionally freeing evicted
        slots first); returns the invocation future."""
        return self._f_decode.submit(
            self._params(), cfg=self.cfg, handle=self.handle, k=int(k),
            free_slots=tuple(free_slots), ttl_s=self.ttl_s)

    def observe(self, reply: dict) -> dict:
        """Fold a worker reply into the client mirrors (cursor /
        occupancy)."""
        if self.paged:
            if "occupancy" in reply:
                self.occupancy = dict(reply["occupancy"])
            return reply
        self._cursor = int(reply["idx"])
        return reply

    def observe_paged_prefill(self, reply: dict) -> dict:
        """Fold a paged prefill reply: occupancy mirror + prefix counters
        (a slot whose matched head is non-empty counts as a prefix hit —
        the paged analogue of the exact-match store hit)."""
        self.observe(reply)
        for info in reply.get("slots", {}).values():
            if info.get("live"):
                if info.get("matched", 0) > 0:
                    self.prefix_hits += 1
                else:
                    self.prefix_misses += 1
        return reply

    # -------------------------------------------------------- migration --
    def control(self, op: str, body: bytes = b"", **data):
        """One state CONTROL verb against this engine's pinned worker
        (direct registry call on in-process backends).  Returns
        ``(reply_data, reply_body)``."""
        if self._local_state:
            if op in (MIGRATE_EXTRACT_OP, MIGRATE_INSERT_OP):
                return migration_control(op, data, body)
            return state.control(op, data), b""
        backend = self.server.session.backend
        reply = dict(backend.state_control(self.affinity, op, body=body,
                                           **data))
        return reply, reply.pop("_body", b"")

    def extract_rows(self, slots, *, free: bool = True) -> list[dict]:
        """Pull finished rows out of this arena (freeing their slots by
        default) as per-row client-side entries — the prefill half of a
        disaggregated hand-off.  Synchronous round-trip; run it off the
        event loop like every other engine call."""
        _, body = self.control(
            MIGRATE_EXTRACT_OP, handle=self.handle,
            slots=tuple(int(s) for s in slots),
            width=self.cursor0 if self.cfg.family != "ssm" else 0,
            free=bool(free), ttl_s=self.ttl_s)
        return split_rows(body)

    def insert_rows(self, slots, entries) -> None:
        """Insert migrated per-row entries into this arena's ``slots`` —
        the decode half.  The arena must already exist (``submit_admit([])``
        creates one); an expired lease raises the state-lost ``KeyError``."""
        width = 0          # read the window off the rows themselves: the
        for k, v in entries[0]["rows"].items():   # source arena chose it
            if k in SEQ_CACHE_KEYS:
                width = int(np.asarray(v).shape[2])
                break
        reply, _ = self.control(
            MIGRATE_INSERT_OP, body=merge_rows(entries),
            handle=self.handle, slots=tuple(int(s) for s in slots),
            width=width, ttl_s=self.ttl_s)
        self._cursor = int(reply.get("idx", self._cursor))

    def choose_k(self, max_remaining: int) -> int:
        """Decode-chunk length: the quantum, shrunk (to a pow2 bucket, so
        compiled step programs stay shared) when every live row is nearly
        done — bounded overshoot, bounded compile variants."""
        return shape_bucket(max(1, min(self.quantum, max_remaining)))

    # --------------------------------------------------------- heartbeat --
    def renew_lease(self) -> bool:
        """Extend this arena's lease WITHOUT touching its data — the
        ``state_renew`` heartbeat verb (ISSUE 10).  Returns whether the
        handle was still resident; any transport failure reads as "not
        renewed" (the next engine call will surface the real error)."""
        try:
            reply, _ = self.control("state_renew", handle=self.handle,
                                    ttl_s=self.ttl_s)
            return bool(reply.get("renewed", False))
        except Exception:
            return False

    def start_heartbeat(self, interval_s: float | None = None) -> None:
        """Run a daemon thread renewing the lease every ``ttl/3`` (or
        ``interval_s``).  ``get``/``lease`` renew only on touch, so a long
        client-side stall between engine calls — a chaos-injected straggle,
        a GC pause — would otherwise expire the lease under LIVE rows.  A
        separate thread keeps the lease honest precisely when the loop
        thread is stuck waiting.  Reads ``self.handle`` each beat, so it
        follows :meth:`reset` to the replacement arena automatically."""
        if self._hb_thread is not None or self._closed:
            return
        interval = (float(interval_s) if interval_s is not None
                    else self.ttl_s / 3.0)
        stop = threading.Event()

        def beat() -> None:
            while not stop.wait(interval):
                self.renew_lease()

        self._hb_stop = stop
        self._hb_thread = threading.Thread(
            target=beat, name=f"repro-heartbeat-{self.handle[:8]}",
            daemon=True)
        self._hb_thread.start()

    def stop_heartbeat(self) -> None:
        if self._hb_stop is not None:
            self._hb_stop.set()
        self._hb_thread = None
        self._hb_stop = None

    # ------------------------------------------------------------- reset --
    def reset(self) -> None:
        """After state loss (worker respawn / lease expiry): new handle,
        cold mirrors.  The next admission rebuilds the arena."""
        self.handle = uuid.uuid4().hex
        self._cursor = self.cursor0
        self._prefix.clear()
        self._prefix_total = 0

    def close(self) -> None:
        """Release the worker-side lease (best effort, idempotent)."""
        if self._closed:
            return
        self._closed = True
        self.stop_heartbeat()
        try:
            if self._local_state:
                state.release(self.handle)
            else:
                backend = self.server.session.backend
                ctrl = getattr(backend, "state_control", None)
                if ctrl is not None:
                    ctrl(self.affinity, "state_release", handle=self.handle)
        except Exception:
            pass                    # lease TTL reclaims it regardless
