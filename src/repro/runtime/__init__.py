"""repro.runtime — execution hosts (training, serving, worker sandboxes).

Exports are lazy: ``runtime.sandbox`` / ``runtime.worker_host`` sit *below*
the dispatch layer (the worker side of every transport), while ``server``
and ``trainer`` sit above it (they drive a ``cloud.Session``).  Importing
the package must therefore not pull the high-level modules, or
``dispatch → runtime.sandbox`` would cycle back through ``cloud``.
"""
from typing import Any

_EXPORTS = {
    "Completion": ".server", "LMServer": ".server", "Request": ".server",
    "make_generate_fn": ".server", "decode_bucket": ".server",
    "shape_bucket": ".server", "pack_prompts": ".server",
    "EngineClient": ".engine", "engine_prefill": ".engine",
    "engine_decode": ".engine", "prefix_key": ".engine",
    "is_state_lost": ".engine",
    "SimulatedPreemption": ".trainer", "TrainReport": ".trainer",
    "train": ".trainer",
    "SandboxHost": ".sandbox", "WorkerInstance": ".sandbox",
    "FaultPlan": ".sandbox", "WorkerCrash": ".sandbox",
    "WorkerHost": ".worker_host", "serve_http": ".worker_host",
    "stdio_main": ".worker_host",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str) -> Any:
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    from importlib import import_module
    return getattr(import_module(module, __package__), name)
