from .server import Completion, LMServer, Request, make_generate_fn
from .trainer import SimulatedPreemption, TrainReport, train
