"""Radix (compressed trie) index over block-aligned token runs.

The paged KV arena (ISSUE 7) shares common-prompt-prefix KV *blocks*
between rows copy-free: a block holds ``block_size`` consecutive tokens'
K/V, and two prompts that agree on their first ``n × block_size`` tokens
can reference the same ``n`` physical blocks.  This index is the lookup
structure that makes the sharing findable: keys are token sequences
consumed a whole block at a time, values are one payload per block (the
engine stores physical block ids; the fleet router stores member
indices).

Structure: a compressed trie.  Each node carries a *run* of one or more
consecutive blocks (``tokens``: the run's flat token tuple, ``vals``: one
payload per block).  Matching walks block-by-block; an insert that
diverges mid-run splits the node at the block boundary where agreement
ends — block granularity means a split can never cut through a payload.

Eviction is LRU over *leaf* runs (a monotone clock stamps every node a
match or insert touches), bounded by a token budget.  The index never
frees anything itself — ``evict`` returns the payloads it dropped and the
caller (which refcounts blocks across rows AND this index) decides when a
physical block is actually reusable.  That is what makes "LRU eviction
never frees a block a live row references" hold by construction.
"""
from __future__ import annotations

from typing import Any, Sequence


class _Node:
    __slots__ = ("tokens", "vals", "children", "parent", "stamp")

    def __init__(self, tokens: tuple, vals: list, parent: "_Node | None"):
        self.tokens = tokens            # flat run, len == len(vals) * bs
        self.vals = vals                # one payload per block in the run
        self.children: dict[tuple, _Node] = {}   # first block -> child
        self.parent = parent
        self.stamp = 0

    def edge(self, bs: int) -> tuple:
        """The child-map key: this run's first block."""
        return self.tokens[:bs]


class RadixIndex:
    """Block-aligned radix index: token runs -> one payload per block."""

    def __init__(self, block_size: int, budget_tokens: int = 1 << 16):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.bs = int(block_size)
        self.budget = int(budget_tokens)
        self.root = _Node((), [], None)
        self.tokens = 0                 # total tokens resident in the index
        self._clock = 0

    # ------------------------------------------------------------ helpers --
    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock

    def _blocks(self, tokens: Sequence[int]) -> list[tuple]:
        bs = self.bs
        n = len(tokens) // bs
        t = tuple(int(x) for x in tokens[:n * bs])
        return [t[i * bs:(i + 1) * bs] for i in range(n)]

    def _split(self, node: _Node, at_block: int) -> None:
        """Split ``node`` so its run keeps blocks [0, at_block) and a new
        child inherits blocks [at_block, ...) plus the old children."""
        bs = self.bs
        tail = _Node(node.tokens[at_block * bs:], node.vals[at_block:], node)
        tail.children = node.children
        for ch in tail.children.values():
            ch.parent = tail
        tail.stamp = node.stamp
        node.tokens = node.tokens[:at_block * bs]
        node.vals = node.vals[:at_block]
        node.children = {tail.edge(bs): tail}

    # ------------------------------------------------------------- lookup --
    def match(self, tokens: Sequence[int]) -> tuple[int, list[Any]]:
        """Longest block-aligned prefix of ``tokens`` resident in the index.

        Returns ``(matched_token_count, payloads)`` — one payload per
        matched block, in order.  Touches every node on the matched path
        (LRU renewal)."""
        blocks = self._blocks(tokens)
        node, i, payloads = self.root, 0, []
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                break
            nb = len(child.vals)
            j = 0
            while j < nb and i + j < len(blocks) \
                    and child.tokens[j * self.bs:(j + 1) * self.bs] \
                    == blocks[i + j]:
                j += 1
            if j == 0:
                break
            payloads.extend(child.vals[:j])
            self._touch(child)
            i += j
            if j < nb:
                break                   # diverged (or ran out) mid-run
            node = child
        return i * self.bs, payloads

    # ------------------------------------------------------------- insert --
    def insert(self, tokens: Sequence[int], payloads: Sequence[Any],
               overwrite: bool = False) -> list[Any]:
        """Insert the full blocks of ``tokens`` with per-block payloads.

        Returns the payloads *newly stored* (blocks already present are
        left alone unless ``overwrite``, which replaces their payloads in
        place without counting them as new — the router's reassignment
        path; the engine never overwrites because equal tokens mean equal
        block content)."""
        blocks = self._blocks(tokens)
        if len(payloads) < len(blocks):
            raise ValueError(
                f"insert needs one payload per block: {len(blocks)} blocks, "
                f"{len(payloads)} payloads")
        node, i = self.root, 0
        while i < len(blocks):
            child = node.children.get(blocks[i])
            if child is None:
                run = tuple(t for b in blocks[i:] for t in b)
                vals = list(payloads[i:len(blocks)])
                leaf = _Node(run, vals, node)
                node.children[leaf.edge(self.bs)] = leaf
                self._touch(leaf)
                self.tokens += len(run)
                return vals
            nb = len(child.vals)
            j = 0
            while j < nb and i + j < len(blocks) \
                    and child.tokens[j * self.bs:(j + 1) * self.bs] \
                    == blocks[i + j]:
                if overwrite:
                    child.vals[j] = payloads[i + j]
                j += 1
            self._touch(child)
            if j < nb:
                if i + j == len(blocks):
                    return []           # fully contained in this run
                self._split(child, j)   # diverge mid-run: split at boundary
            i += j
            node = child
        return []

    # ------------------------------------------------------------ evict ----
    def _leaves(self) -> list[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root and not n.children:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def _drop(self, node: _Node) -> list[Any]:
        parent = node.parent
        del parent.children[node.edge(self.bs)]
        self.tokens -= len(node.tokens)
        return list(node.vals)

    def evict(self, budget: int | None = None) -> list[Any]:
        """Drop least-recently-touched leaf runs until the resident token
        count fits ``budget`` (default: the constructor's).  Returns every
        payload dropped — the caller owns what to do with them
        (refcount decrement, then free only at zero)."""
        budget = self.budget if budget is None else int(budget)
        dropped: list[Any] = []
        while self.tokens > budget:
            leaves = self._leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.stamp)
            dropped.extend(self._drop(victim))
        return dropped

    def evict_blocks(self, n_blocks: int) -> list[Any]:
        """Drop LRU leaves until at least ``n_blocks`` payloads came out
        (or the index is empty) — the allocation-pressure path."""
        dropped: list[Any] = []
        while len(dropped) < n_blocks:
            leaves = self._leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.stamp)
            dropped.extend(self._drop(victim))
        return dropped

    # ------------------------------------------------------------- stats ---
    @property
    def n_nodes(self) -> int:
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    def stats(self) -> dict:
        return {"tokens": self.tokens, "nodes": self.n_nodes,
                "budget": self.budget}
