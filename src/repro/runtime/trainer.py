"""Fault-tolerant training runtime.

The loop composes every substrate piece: sharded data, the jitted
train_step entry point, async checkpointing, restart discovery, and a
failure-injection hook that simulates a worker/sandbox loss mid-run — the
recovery path (restore newest committed checkpoint, skip data ahead,
continue) is exactly what a 1000-node deployment does on a preemption.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint.store import AsyncCheckpointer, latest_step, restore
from ..configs.base import ModelConfig
from ..data.pipeline import SyntheticLM
from ..models import build_model, make_train_step
from ..optim import AdamW
from ..sharding import AxisRules, tree_shardings, use_rules


class SimulatedPreemption(RuntimeError):
    """A node vanished (spot reclaim / hardware fault)."""


@dataclass
class TrainReport:
    steps_run: int = 0
    restarts: int = 0
    losses: list[float] = field(default_factory=list)
    step_times_s: list[float] = field(default_factory=list)
    restored_from: list[int] = field(default_factory=list)

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def train(cfg: ModelConfig, *, steps: int, global_batch: int, seq_len: int,
          mesh=None, ckpt_dir: str | None = None, ckpt_every: int = 50,
          peak_lr: float = 3e-3, seed: int = 0,
          fail_at: set[int] | None = None,
          max_restarts: int = 4,
          on_step: Callable[[int, dict], None] | None = None) -> TrainReport:
    """Run (or resume) a training job; survives injected preemptions."""
    rules = AxisRules(mesh) if mesh is not None else None
    model = build_model(cfg)
    opt = AdamW(peak_lr=peak_lr, warmup=max(5, steps // 20),
                total_steps=steps)
    data = SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed=seed)
    report = TrainReport()
    fail_at = fail_at or set()

    def init_state():
        params, specs = model.init(jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
        if rules is not None:
            p_sh = tree_shardings(rules, params, specs)
            o_sh = tree_shardings(rules, opt_state, opt.state_specs(specs))
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
        return params, opt_state

    step_fn = make_train_step(model, opt)
    if rules is not None:
        with use_rules(rules):
            jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    params, opt_state = init_state()
    start = 0
    if ckpt_dir:
        newest = latest_step(ckpt_dir)
        if newest is not None:
            params, opt_state = restore(
                ckpt_dir, newest, (params, opt_state))
            start = newest
            report.restored_from.append(newest)

    step = start
    while step < steps:
        try:
            if step in fail_at:
                fail_at.discard(step)
                raise SimulatedPreemption(f"node lost at step {step}")
            batch = (data.device_batch(step, rules.mesh, rules)
                     if rules is not None else
                     {k: jax.numpy.asarray(v)
                      for k, v in data.batch(step).items()})
            t0 = time.perf_counter()
            with use_rules(rules):
                params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            report.step_times_s.append(time.perf_counter() - t0)
            report.losses.append(loss)
            report.steps_run += 1
            if on_step:
                on_step(step, metrics)
            step += 1
            if ckpt and step % ckpt_every == 0:
                ckpt.save(step, (params, opt_state))
        except SimulatedPreemption:
            report.restarts += 1
            if report.restarts > max_restarts:
                raise
            # recovery: fresh state, restore newest committed checkpoint,
            # deterministic data skip-ahead puts us back on-stream.
            if ckpt:
                ckpt.wait()
            params, opt_state = init_state()
            newest = latest_step(ckpt_dir) if ckpt_dir else None
            if newest is not None:
                params, opt_state = restore(ckpt_dir, newest,
                                            (params, opt_state))
                step = newest
                report.restored_from.append(newest)
            else:
                step = 0
    if ckpt:
        ckpt.save(steps, (params, opt_state))
        ckpt.close()
    return report
