"""Worker-resident serving state: leased handles over a process registry.

Cppless functions are stateless by contract — and Hellerstein et al.'s
critique (PAPERS.md) is that this forces serving systems to ship data to
code on every call.  Iteration-level serving (ISSUE 5) needs the opposite
on its hottest path: the KV-cache arena a decode loop advances must stay
*resident* where the compute runs, across invocations.  This module is
that residence — a process-level registry of state entries keyed by
client-generated handles, living in whatever process executes entry
points:

* in-process backends (``inline``/``threads``) share this exact module
  with the client — the arena is process-local and free;
* out-of-process workers (``processes``/``http``/``http-aio``) hold their
  own copy, reached by pinning every invocation that names a handle to
  one worker (``FunctionConfig.affinity``) and managed through wire
  ``CONTROL`` verbs (``state_lease`` / ``state_release`` / ``state_stats``
  in :mod:`repro.runtime.worker_host`).

Leases, not ownership: every touch renews a TTL, and expired entries are
reclaimed on the next registry access — a client that died mid-serve
cannot pin worker memory forever.  A reclaimed (or respawned-worker)
handle surfaces as ``KeyError`` mentioning "state handle", which the wire
reconstructs client-side; schedulers treat it as *state lost* and rebuild
rather than retry.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

# test seam: unit tests monkeypatch this to drive TTL expiry without sleeping
_now = time.monotonic

DEFAULT_TTL_S = 60.0


@dataclass
class StateEntry:
    handle: str
    data: dict[str, Any]
    ttl_s: float
    deadline: float
    created: float = field(default_factory=lambda: _now())
    touches: int = 0


_ENTRIES: dict[str, StateEntry] = {}
_LOCK = threading.Lock()


def _state_lost(handle: str) -> KeyError:
    # KeyError is a builtin: the wire reconstructs it client-side, and the
    # "state handle" marker is the documented state-lost signature
    return KeyError(f"state handle {handle!r} not resident "
                    "(expired lease, released, or a fresh worker process)")


def _sweep_locked(now: float) -> list[str]:
    dead = [h for h, e in _ENTRIES.items() if e.deadline < now]
    for h in dead:
        del _ENTRIES[h]
    return dead


def sweep() -> list[str]:
    """Reclaim every expired lease; returns the reclaimed handles."""
    with _LOCK:
        return _sweep_locked(_now())


def lease(handle: str, *, ttl_s: float = DEFAULT_TTL_S,
          make: Callable[[], dict] | None = None) -> dict[str, Any]:
    """Fetch-or-create the state under ``handle``, renewing its lease.

    ``make()`` builds the initial data dict on first use; without it a
    missing handle raises the state-lost ``KeyError``.
    """
    now = _now()
    with _LOCK:
        _sweep_locked(now)
        e = _ENTRIES.get(handle)
        if e is None:
            if make is None:
                raise _state_lost(handle)
            e = StateEntry(handle=handle, data=make(), ttl_s=ttl_s,
                           deadline=now + ttl_s)
            _ENTRIES[handle] = e
        e.ttl_s = ttl_s
        e.deadline = now + ttl_s
        e.touches += 1
        return e.data


def get(handle: str, *, ttl_s: float | None = None) -> dict[str, Any]:
    """Fetch existing state, renewing its lease; ``KeyError`` if lost."""
    now = _now()
    with _LOCK:
        _sweep_locked(now)
        e = _ENTRIES.get(handle)
        if e is None:
            raise _state_lost(handle)
        if ttl_s is not None:
            e.ttl_s = ttl_s
        e.deadline = now + e.ttl_s
        e.touches += 1
        return e.data


def release(handle: str) -> bool:
    """Drop a handle (idempotent); returns whether it was resident."""
    with _LOCK:
        return _ENTRIES.pop(handle, None) is not None


def renew(handle: str, *, ttl_s: float | None = None) -> bool:
    """Extend a lease WITHOUT touching the data — the heartbeat verb.

    ``get``/``lease`` renew only on touch, so a long client-side stall
    (GC pause, chaos-injected straggle) between engine calls can expire a
    lease under a *live* row.  The batcher's heartbeat sends this between
    engine calls to keep the lease honest; returns whether the handle was
    still resident (a False tells the client the state is already gone).
    """
    now = _now()
    with _LOCK:
        _sweep_locked(now)
        e = _ENTRIES.get(handle)
        if e is None:
            return False
        if ttl_s is not None:
            e.ttl_s = float(ttl_s)
        e.deadline = now + e.ttl_s
        e.touches += 1
        return True


def expire_all(handles: list[str] | None = None) -> list[str]:
    """Force leases to expire NOW (chaos injection: ``lease.expired``).

    Backdates the deadline of every named handle (default: all resident
    handles) so the next registry access reclaims them — the next engine
    call on an affected handle surfaces the state-lost ``KeyError`` and
    exercises the replay-failover path without killing the process.
    """
    now = _now()
    with _LOCK:
        targets = list(_ENTRIES) if handles is None else \
            [h for h in handles if h in _ENTRIES]
        for h in targets:
            _ENTRIES[h].deadline = now - 1.0
        return targets


def stats() -> dict[str, Any]:
    now = _now()
    with _LOCK:
        _sweep_locked(now)
        detail = {}
        # paged-arena occupancy rollup: live tokens / allocated blocks /
        # shared (refcount > 1) blocks across every resident arena on this
        # worker — block reuse is observable, not inferred (ISSUE 7)
        arena = {"live_tokens": 0, "allocated_blocks": 0, "shared_blocks": 0}
        for h, e in _ENTRIES.items():
            d = {"age_s": round(now - e.created, 3),
                 "ttl_s": e.ttl_s,
                 "expires_in_s": round(e.deadline - now, 3),
                 "touches": e.touches}
            occ = e.data.get("occupancy")
            if occ:
                d["occupancy"] = dict(occ)
                for key in arena:
                    arena[key] += int(occ.get(key, 0))
            detail[h] = d
        return {"handles": sorted(_ENTRIES),
                "count": len(_ENTRIES),
                "prefix_tokens": sum(
                    int(e.data.get("prefix_tokens", 0))
                    for e in _ENTRIES.values()),
                "arena": arena,
                # per-handle lease detail: what a scale-down refusal names
                # and what fleet observability reports per worker
                "detail": detail}


def control(op: str, data: dict[str, Any]) -> dict[str, Any]:
    """The CONTROL-verb surface shared by the worker host and local
    backends: lease renewal, release, and observability."""
    if op == "state_lease":
        handle = data["handle"]
        ttl_s = float(data.get("ttl_s", DEFAULT_TTL_S))
        try:
            get(handle, ttl_s=ttl_s)
            return {"ok": True, "known": True}
        except KeyError:
            return {"ok": True, "known": False}
    if op == "state_renew":
        ttl = data.get("ttl_s")
        return {"ok": True,
                "renewed": renew(data["handle"],
                                 ttl_s=None if ttl is None else float(ttl))}
    if op == "state_release":
        return {"ok": True, "released": release(data["handle"])}
    if op == "state_stats":
        return stats()
    raise ValueError(f"unknown state op {op!r}")
