"""Serverless LM serving — the paper's offload model applied to inference.

Each generation request is a stateless task (prompt -> completion), exactly
the paper's fork-join unit.  The serve path is deployed through the same
core pipeline as any Cppless function: AOT-compiled entry points (prefill +
decode), content-addressed names in the manifest, binary payloads, and the
pooled dispatcher with retry/hedging — so LM serving inherits the fault-
tolerance and cost accounting (GB-seconds per request) of the framework.

Two scheduling modes share one pack/dispatch/unpack core
(``submit_wave`` / ``unpack_wave``):

* **waves** — :meth:`LMServer.serve`: fixed fork-join, requests
  pre-partitioned into ``wave_size`` batches, each wave one task;
* **continuous** — :class:`repro.serving.batcher.ContinuousBatcher`:
  arriving requests are admitted into decode batches as slots free up,
  grouped by decode-length bucket so a short request never pays for a
  long neighbour's tail.  On backends with worker-resident state it
  upgrades to *iteration-level* scheduling: prefill and decode are split
  into the two entry points of :mod:`repro.runtime.engine`, the KV cache
  stays resident on the worker, and admission happens every ``k`` decode
  steps instead of between batches (ISSUE 5).

Decode length is *bucketed* (next power of two ≥ the batch's largest
``max_new``): one deployed entry point per bucket, cached, so a batch only
decodes as far as its own requests need instead of always paying the
server-wide maximum.  ``grow_cache`` additionally rounds the grown cache
capacity up to a pow2 bucket, so nearby ``s + max_new`` combinations share
one compiled decode program.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..cloud import Session, gather, session_for
from ..dispatch import Dispatcher
from ..models import build_model
from ..models.api import grow_cache
from ..serialization import prune_artifacts, put_artifact, release_artifact
from ..configs.base import ModelConfig


@dataclass
class Request:
    prompt: list[int]
    max_new: int = 16


@dataclass
class Completion:
    tokens: list[int]
    latency_ms: float = 0.0
    cost_gb_s: float = 0.0
    # time to first token (ms).  Batch-level schedulers have no token
    # stream — the whole batch joins at once — so TTFT degenerates to the
    # completion latency; the iteration-level scheduler fills in the real
    # prefill-done time (ISSUE 5).  None = "same as latency_ms".
    ttft_ms: float | None = None
    # per-token arrival times (ms since request arrival), stamped ONCE by
    # the iteration-level scheduler at each decode-chunk reply —
    # token_times_ms[0] == ttft_ms by construction.  Tokens landing in the
    # same chunk share a timestamp (they genuinely arrived together).
    # None on batch-level paths, where there is no token stream to stamp.
    token_times_ms: list[float] | None = None
    # True when this completion survived a failover: the row's worker (or
    # its state lease) was lost mid-decode and the scheduler re-prefilled
    # prompt + generated-so-far elsewhere and kept decoding (ISSUE 10).
    # Greedy decode makes the tokens bit-identical either way; the flag
    # (plus the latency the replay added) is the only observable trace.
    recovered: bool = False

    @property
    def ttft(self) -> float:
        return self.latency_ms if self.ttft_ms is None else self.ttft_ms


def shape_bucket(n: int) -> int:
    """Next power of two ≥ ``n`` — the shape-stability quantum."""
    return 1 << max(0, int(n) - 1).bit_length()


def decode_bucket(max_new: int) -> int:
    """Decode-length bucket: next power of two ≥ ``max_new``.

    One deployed generate function per bucket bounds the number of AOT
    compilations at log2(longest generation) while letting short batches
    skip a long server-wide decode tail — the compute the continuous
    batcher saves by grouping like-length requests.
    """
    return shape_bucket(max_new)


def pack_prompts(prompts: Sequence[Sequence[int]], pad: int = 0,
                 min_rows: int = 1):
    """Pack prompts into a shape-*bucketed* token batch; returns
    ``(tokens (B, S) int32, lengths (B,) int32)``.

    Entry-point identity is shape-dependent (the AOT stable name
    fingerprints abstract payloads), so a serving scheduler that emitted
    whatever (batch, seqlen) arrived would recompile on nearly every
    batch — multi-second stalls in the serve path.  Both dims therefore
    round up to powers of two: at most log2 variants per decode bucket
    ever compile, at worst 2× padding compute — the standard
    shape-bucketing trade every XLA serving system makes.

    Rows are left-padded (last real token aligned) with ``pad`` (the
    model's ``cfg.pad_id`` — NOT a sentinel: ``lengths`` is the source of
    truth for what is padding, and the model families mask pad slots out
    of attention and recurrent state, so packing is batch-composition-
    invariant for ragged prompt sets).  Filler rows below the row bucket
    are all-pad with length 0 — fully masked, sliced off at unpack.
    ``min_rows`` pins the row bucket from below: a scheduler that always
    passes its full batch size gets exactly ONE compiled shape per decode
    bucket — partial tail batches pad instead of compiling a fresh entry
    point mid-serve.
    """
    if not prompts:
        raise ValueError("pack_prompts: empty prompt list — nothing to "
                         "pack into a batch")
    for i, p in enumerate(prompts):
        if len(p) == 0:
            raise ValueError(
                f"pack_prompts: prompt {i} is empty — a zero-length prompt "
                "has no last token to decode from (it would silently become "
                "an all-pad row)")
    b = shape_bucket(max(len(prompts), min_rows))
    s = shape_bucket(max(len(p) for p in prompts))
    out = np.full((b, s), pad, np.int32)
    lengths = np.zeros((b,), np.int32)   # filler rows: length 0, fully masked
    for i, p in enumerate(prompts):
        out[i, s - len(p):] = p          # left-pad so last token aligns
        lengths[i] = len(p)
    return out, lengths


def make_generate_fn(cfg: ModelConfig, max_new: int):
    """Build the stateless serve task:
    (params, tokens, lengths) -> generated ids.

    ``lengths`` (B,) int32 rides with every batch: prefill masks each row's
    left pad out of attention/recurrent state, and the cache's ``start``
    plane keeps masking it through decode — so the generated tokens for a
    prompt do not depend on what it was packed with.

    Capture discipline (the Cppless contract): the closure captures only
    *data* (``cfg``, ``max_new``) — both ship in the payload (``ModelConfig``
    is a registered wire type), so the frozen closure rebuilds in any
    worker process that has the package tree.  The model's entry points
    are deliberately NOT captured as callables: they are closures carrying
    their own data captures, which cannot cross the wire — instead the
    task body rebuilds them from ``cfg`` (cheap: ``build_model`` only
    defines functions; the real cost is the AOT compile the worker pays
    once per cold start anyway).
    """
    def generate(params, tokens, lengths):
        model = build_model(cfg)
        b, s = tokens.shape
        logits, cache = model.prefill(params, {"tokens": tokens,
                                               "lengths": lengths})
        cache = grow_cache(cfg, cache, s + max_new)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

        def step(carry, _):
            cache, tok = carry
            logits, cache = model.decode(params, cache, tok)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return (cache, nxt), tok[:, 0]

        (_, _), toks = jax.lax.scan(step, (cache, tok), None,
                                    length=max_new)
        return jnp.moveaxis(toks, 0, 1)           # (B, max_new)

    return generate


class LMServer:
    """Serverless serving facade over a ``cloud.Session``.

    Generate tasks are *bound* once per decode-length bucket
    (``session.function``); waves are submitted concurrently and gathered
    in order — per-wave accounting stays correct because entry-point stats
    travel with each result.  ``submit_wave`` / ``unpack_wave`` are the
    shared pack/dispatch/unpack core both the wave scheduler (here) and
    the continuous batcher (``repro.serving.batcher``) drive.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 session: Session | None = None,
                 dispatcher: Dispatcher | None = None,
                 memory_mb: int = 2048, max_new: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_new = max_new
        self._memory_mb = memory_mb
        self.session = session_for(session, dispatcher)
        self._gen_fns: dict[int, object] = {}
        # params are deployed ONCE to the content-addressed artifact store;
        # every batch payload carries the (path, sha) pointer instead of
        # re-shipping the model — measured ~98% of serve-payload bytes
        self._params_ref = put_artifact(params)
        # the default-bucket handle, kept under the historical name
        self.generate = self._generate_for(max_new)

    # ------------------------------------------------------------ teardown
    def close(self, *, prune: bool = True) -> None:
        """Release this server's params artifact and (by default) prune the
        content-addressed store: blobs still referenced by other live
        servers in this process — or passed to ``prune_artifacts(keep=…)``
        by the caller — survive; everything unreferenced is unlinked, so
        long-running serve hosts don't accumulate every params tree they
        ever deployed.  Idempotent."""
        ref, self._params_ref = self._params_ref, None
        if ref is None:
            return
        release_artifact(ref)
        if prune:
            prune_artifacts()

    def __enter__(self) -> "LMServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _generate_for(self, max_new: int):
        """The bound generate function for ``max_new``'s decode bucket
        (deployed on first use, cached after)."""
        bucket = decode_bucket(max_new)
        fn = self._gen_fns.get(bucket)
        if fn is None:
            fn = self.session.function(
                make_generate_fn(self.cfg, bucket),
                name=f"serve_{self.cfg.name}_d{bucket}",
                memory_mb=self._memory_mb, serializer="binary")
            self._gen_fns[bucket] = fn
        return fn

    # ----------------------------------------------- pack/dispatch/unpack
    def submit_wave(self, requests: Sequence[Request], *, min_rows: int = 1):
        """Pack ``requests`` into one shape-bucketed decode batch and
        dispatch it as a single serverless task; returns the invocation
        future.  Schedulers pass their nominal batch size as ``min_rows``
        so tail batches pad to the warmed shape instead of compiling a
        fresh one."""
        if self._params_ref is None:
            raise RuntimeError("LMServer is closed (params artifact "
                               "released)")
        tokens, lengths = pack_prompts([r.prompt for r in requests],
                                       pad=self.cfg.pad_id,
                                       min_rows=min_rows)
        gen = self._generate_for(max(r.max_new for r in requests))
        return gen.submit(self._params_ref, jnp.asarray(tokens),
                          jnp.asarray(lengths))

    def unpack_wave(self, requests: Sequence[Request], fut) -> list[Completion]:
        """Join one dispatched batch: per-request token trim + pro-rata
        billing from the wave's invocation record."""
        out = np.asarray(fut.result())
        rec = fut.record
        return [Completion(
            tokens=[int(t) for t in out[i][:r.max_new]],
            latency_ms=(rec.server_s * 1000.0) if rec else 0.0,
            cost_gb_s=(rec.billed_gb_s if rec else 0.0)
            / max(1, len(requests)))
            for i, r in enumerate(requests)]

    # legacy private names (pre-ISSUE-3 callers)
    _submit_wave = submit_wave
    _unpack_wave = unpack_wave

    def serve_wave(self, requests: Sequence[Request]) -> list[Completion]:
        """One batched wave: pack requests, dispatch, unpack."""
        return self.unpack_wave(requests, self.submit_wave(requests))

    def serve(self, requests: Sequence[Request], wave_size: int = 8,
              max_inflight: int = 4) -> list[Completion]:
        """Fork-join over request waves (each wave = one serverless task).

        Waves run concurrently on the backend; completions return in
        request order.  ``max_inflight`` bounds queued payloads — each one
        embeds the serialized params, so unbounded submission would hold
        n_waves copies of the model in memory at once.
        """
        max_inflight = max(1, max_inflight)       # 0/negative = synchronous
        waves = [requests[i:i + wave_size]
                 for i in range(0, len(requests), wave_size)]
        futs: list = []
        for i, w in enumerate(waves):
            if i >= max_inflight:
                futs[i - max_inflight].result()   # free the oldest payload
            futs.append(self.submit_wave(w, min_rows=wave_size))
        gather(futs)                      # settle, surface first failure
        out: list[Completion] = []
        for w, f in zip(waves, futs):
            out.extend(self.unpack_wave(w, f))
        return out

    @property
    def cost_report(self):
        return self.session.cost
