"""Serverless LM serving — the paper's offload model applied to inference.

Each generation request is a stateless task (prompt -> completion), exactly
the paper's fork-join unit.  The serve path is deployed through the same
core pipeline as any Cppless function: AOT-compiled entry points (prefill +
decode), content-addressed names in the manifest, binary payloads, and the
pooled dispatcher with retry/hedging — so LM serving inherits the fault-
tolerance and cost accounting (GB-seconds per request) of the framework.

Batched mode packs concurrent requests into one decode batch (continuous-
batching-lite: a fresh batch per wave) and dispatches the *wave* as a task.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import FunctionConfig, RemoteFunction
from ..dispatch import Dispatcher
from ..models import build_model
from ..configs.base import ModelConfig


@dataclass
class Request:
    prompt: list[int]
    max_new: int = 16


@dataclass
class Completion:
    tokens: list[int]
    latency_ms: float = 0.0
    cost_gb_s: float = 0.0


def _pad_prompts(prompts: Sequence[Sequence[int]], pad: int = 0):
    b = len(prompts)
    s = max(len(p) for p in prompts)
    out = np.full((b, s), pad, np.int32)
    for i, p in enumerate(prompts):
        out[i, s - len(p):] = p          # left-pad so last token aligns
    return out


def make_generate_fn(cfg: ModelConfig, max_new: int):
    """Build the stateless serve task: (params, tokens) -> generated ids.

    Capture discipline (the Cppless contract): the closure's *data*
    captures (`max_new`) ship in the payload; everything model-shaped is
    captured as *callables*, which travel with the deployed artifact like
    statically-linked deps, not over the wire.
    """
    from ..models.api import grow_cache
    model = build_model(cfg)
    prefill, decode = model.prefill, model.decode
    grow = functools.partial(grow_cache, cfg)

    def generate(params, tokens):
        b, s = tokens.shape
        logits, cache = prefill(params, {"tokens": tokens})
        cache = grow(cache, s + max_new)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

        def step(carry, _):
            cache, tok = carry
            logits, cache = decode(params, cache, tok)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return (cache, nxt), tok[:, 0]

        (_, _), toks = jax.lax.scan(step, (cache, tok), None,
                                    length=max_new)
        return jnp.moveaxis(toks, 0, 1)           # (B, max_new)

    return generate


class LMServer:
    """Serverless serving facade over the repro dispatcher."""

    def __init__(self, cfg: ModelConfig, params, *,
                 dispatcher: Dispatcher | None = None,
                 memory_mb: int = 2048, max_new: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_new = max_new
        self.d = dispatcher or Dispatcher()
        self.inst = self.d.create_instance()
        gen = make_generate_fn(cfg, max_new)
        self.remote = RemoteFunction(
            gen, name=f"serve_{cfg.name}",
            config=FunctionConfig(memory_mb=memory_mb, serializer="binary"))

    def serve_wave(self, requests: Sequence[Request]) -> list[Completion]:
        """One batched wave: pack requests, dispatch, unpack."""
        tokens = _pad_prompts([r.prompt for r in requests])
        fut = self.inst.dispatch(self.remote, self.params,
                                 jnp.asarray(tokens))
        out = np.asarray(fut.result())
        rec = fut.record
        comps = []
        for i, r in enumerate(requests):
            comps.append(Completion(
                tokens=[int(t) for t in out[i][:r.max_new]],
                latency_ms=(rec.server_s * 1000.0) if rec else 0.0,
                cost_gb_s=(rec.billed_gb_s if rec else 0.0)
                / max(1, len(requests))))
        return comps

    def serve(self, requests: Sequence[Request],
              wave_size: int = 8) -> list[Completion]:
        """Fork-join over request waves (each wave = one serverless task)."""
        out: list[Completion] = []
        for i in range(0, len(requests), wave_size):
            out.extend(self.serve_wave(requests[i:i + wave_size]))
        return out

    @property
    def cost_report(self):
        return self.inst.cost
