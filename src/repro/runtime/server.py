"""Serverless LM serving — the paper's offload model applied to inference.

Each generation request is a stateless task (prompt -> completion), exactly
the paper's fork-join unit.  The serve path is deployed through the same
core pipeline as any Cppless function: AOT-compiled entry points (prefill +
decode), content-addressed names in the manifest, binary payloads, and the
pooled dispatcher with retry/hedging — so LM serving inherits the fault-
tolerance and cost accounting (GB-seconds per request) of the framework.

Batched mode packs concurrent requests into one decode batch (continuous-
batching-lite: a fresh batch per wave) and dispatches the *wave* as a task.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..cloud import Session, gather, session_for
from ..dispatch import Dispatcher
from ..models import build_model
from ..configs.base import ModelConfig


@dataclass
class Request:
    prompt: list[int]
    max_new: int = 16


@dataclass
class Completion:
    tokens: list[int]
    latency_ms: float = 0.0
    cost_gb_s: float = 0.0


def _pad_prompts(prompts: Sequence[Sequence[int]], pad: int = 0):
    b = len(prompts)
    s = max(len(p) for p in prompts)
    out = np.full((b, s), pad, np.int32)
    for i, p in enumerate(prompts):
        out[i, s - len(p):] = p          # left-pad so last token aligns
    return out


def make_generate_fn(cfg: ModelConfig, max_new: int):
    """Build the stateless serve task: (params, tokens) -> generated ids.

    Capture discipline (the Cppless contract): the closure's *data*
    captures (`max_new`) ship in the payload; everything model-shaped is
    captured as *callables*, which travel with the deployed artifact like
    statically-linked deps, not over the wire.
    """
    from ..models.api import grow_cache
    model = build_model(cfg)
    prefill, decode = model.prefill, model.decode
    grow = functools.partial(grow_cache, cfg)

    def generate(params, tokens):
        b, s = tokens.shape
        logits, cache = prefill(params, {"tokens": tokens})
        cache = grow(cache, s + max_new)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

        def step(carry, _):
            cache, tok = carry
            logits, cache = decode(params, cache, tok)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return (cache, nxt), tok[:, 0]

        (_, _), toks = jax.lax.scan(step, (cache, tok), None,
                                    length=max_new)
        return jnp.moveaxis(toks, 0, 1)           # (B, max_new)

    return generate


class LMServer:
    """Serverless serving facade over a ``cloud.Session``.

    The generate task is *bound* once (``session.function``); waves are
    submitted concurrently and gathered in order — per-wave accounting
    stays correct because entry-point stats travel with each result.
    """

    def __init__(self, cfg: ModelConfig, params, *,
                 session: Session | None = None,
                 dispatcher: Dispatcher | None = None,
                 memory_mb: int = 2048, max_new: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_new = max_new
        self.session = session_for(session, dispatcher)
        self.generate = self.session.function(
            make_generate_fn(cfg, max_new), name=f"serve_{cfg.name}",
            memory_mb=memory_mb, serializer="binary")

    def _submit_wave(self, requests: Sequence[Request]):
        tokens = _pad_prompts([r.prompt for r in requests])
        return self.generate.submit(self.params, jnp.asarray(tokens))

    def _unpack_wave(self, requests: Sequence[Request], fut) -> list[Completion]:
        out = np.asarray(fut.result())
        rec = fut.record
        return [Completion(
            tokens=[int(t) for t in out[i][:r.max_new]],
            latency_ms=(rec.server_s * 1000.0) if rec else 0.0,
            cost_gb_s=(rec.billed_gb_s if rec else 0.0)
            / max(1, len(requests)))
            for i, r in enumerate(requests)]

    def serve_wave(self, requests: Sequence[Request]) -> list[Completion]:
        """One batched wave: pack requests, dispatch, unpack."""
        return self._unpack_wave(requests, self._submit_wave(requests))

    def serve(self, requests: Sequence[Request], wave_size: int = 8,
              max_inflight: int = 4) -> list[Completion]:
        """Fork-join over request waves (each wave = one serverless task).

        Waves run concurrently on the backend; completions return in
        request order.  ``max_inflight`` bounds queued payloads — each one
        embeds the serialized params, so unbounded submission would hold
        n_waves copies of the model in memory at once.
        """
        max_inflight = max(1, max_inflight)       # 0/negative = synchronous
        waves = [requests[i:i + wave_size]
                 for i in range(0, len(requests), wave_size)]
        futs: list = []
        for i, w in enumerate(waves):
            if i >= max_inflight:
                futs[i - max_inflight].result()   # free the oldest payload
            futs.append(self._submit_wave(w))
        gather(futs)                      # settle, surface first failure
        out: list[Completion] = []
        for w, f in zip(waves, futs):
            out.extend(self._unpack_wave(w, f))
        return out

    @property
    def cost_report(self):
        return self.session.cost
