"""Sandbox lifecycle — the transport-agnostic half of every worker runtime.

A FaaS *sandbox* is an execution slot with state the platform (not the
task) manages: it is provisioned cold, reused warm per function, billed per
invocation, and may be lost at any time.  This module owns exactly that
bookkeeping — cold/warm accounting, elastic drain, deterministic fault
injection — around an opaque entry callable
``entry(payload: bytes) -> (bytes, stats)``.

It deliberately knows nothing about *where* the entry runs: the in-process
backends hand it ``Bridge.entry`` directly, the ``processes``/``http``
transports hand it a proxy that ships the payload across a pipe or socket,
and the worker-side :class:`~repro.runtime.worker_host.WorkerHost` uses the
same host to account for the sandboxes living inside one worker process.
That single seam is what makes backends swappable above it
(``dispatch.backends``) and transports swappable below it.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Tuple

from ..obs import metrics as obs_metrics


class WorkerCrash(RuntimeError):
    """Sandbox failure (node loss / worker death) — retried by the dispatcher."""


@dataclass
class WorkerInstance:
    worker_id: int
    function_name: str
    invocations: int = 0
    created_at: float = field(default_factory=time.time)
    busy_s: float = 0.0                # real wall time spent inside entries

    @property
    def is_cold(self) -> bool:
        return self.invocations == 0


@dataclass
class FaultPlan:
    """Deterministic fault/straggler injection for tests and benchmarks."""
    failure_rate: float = 0.0          # P(sandbox crash) per invocation
    straggler_rate: float = 0.0        # P(task straggles)
    straggler_factor: float = 8.0      # straggler duration multiplier
    straggler_sleep_s: float = 0.0     # real extra sleep for stragglers
    seed: int = 0

    def roll(self, task_id: int, attempt: int) -> tuple[bool, bool]:
        rng = random.Random(self.seed * 1_000_003 + task_id * 1009 + attempt)
        fail = rng.random() < self.failure_rate
        straggle = rng.random() < self.straggler_rate
        return fail, straggle


@dataclass(frozen=True)
class ChaosEvent:
    """One injected failure: fire ``kind`` on the ``after``-th invocation
    routed to worker slot ``slot`` (counted from :meth:`ChaosPlan.arm`)."""
    kind: str                          # "kill" | "stall" | "drop" | "expire"
    slot: int                          # worker slot index the event targets
    after: int = 3                     # fire on the Nth armed invoke there
    stall_s: float = 0.0               # client-side stall duration ("stall")

    KINDS = ("kill", "stall", "drop", "expire")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} "
                             f"(one of {self.KINDS})")


class ChaosPlan:
    """Seeded, deterministic cross-process fault injection (ISSUE 10).

    Where :class:`FaultPlan` simulates sandbox loss *inside* the executing
    process, a ChaosPlan is executed for real by the transport client
    against live worker subprocesses: ``kill`` SIGKILLs the slot's worker
    mid-decode, ``drop`` injects a connection loss (exercising the
    ConnectionError→WorkerCrash normalization), ``stall`` sleeps the
    dispatch thread long enough to threaten a state lease (the heartbeat's
    reason to exist), and ``expire`` force-expires the worker's state
    leases via the CONTROL ``chaos`` verb.  Every event is pinned to a
    (slot, Nth-invoke) coordinate, so a given seed replays the identical
    failure schedule run after run.

    The plan starts DISARMED so warmup traffic doesn't consume the invoke
    budget; ``arm()`` resets the counters and starts counting.  Everything
    that fires (and every respawn the transport observes afterwards) is
    appended to a thread-safe event log — ``log()`` is the evidence the
    chaos bench and CI asserts read.
    """

    def __init__(self, events: list[ChaosEvent] | tuple[ChaosEvent, ...] = (),
                 *, seed: int = 0):
        self.events = tuple(events)
        self.seed = seed
        self._lock = threading.Lock()
        self._counts: dict[int, int] = {}
        self._fired: set[int] = set()
        self._log: list[dict] = []
        self._armed = False
        self._t0 = time.monotonic()

    @classmethod
    def kill_member(cls, *, seed: int = 0, n_slots: int = 2,
                    after: int | None = None) -> "ChaosPlan":
        """The canonical chaos schedule: SIGKILL one fleet member's worker
        mid-decode.  Slot and firing point derive from the seed alone, so
        ``--chaos kill-member --seed 7`` is one reproducible failure."""
        rng = random.Random(seed * 1_000_003 + 17)
        slot = rng.randrange(max(1, n_slots))
        if after is None:
            after = 3 + rng.randrange(3)       # past prefill, into decode
        return cls([ChaosEvent("kill", slot=slot, after=after)], seed=seed)

    @property
    def armed(self) -> bool:
        return self._armed

    def arm(self) -> None:
        """Start counting invocations (reset counters; keep the log)."""
        with self._lock:
            self._armed = True
            self._counts.clear()
            self._fired.clear()

    def on_invoke(self, slot: int) -> list[ChaosEvent]:
        """Advance the slot's invoke counter; return events due NOW."""
        if not self._armed:
            return []
        with self._lock:
            n = self._counts.get(slot, 0) + 1
            self._counts[slot] = n
            due = []
            for i, ev in enumerate(self.events):
                if i not in self._fired and ev.slot == slot and ev.after == n:
                    self._fired.add(i)
                    due.append(ev)
            return due

    def record(self, action: str, *, slot: int | None = None,
               **extra) -> None:
        """Append one event to the chaos log (``worker.killed``,
        ``worker.respawned``, ``conn.dropped``, ``lease.expired``, ...)."""
        entry = {"t": round(time.monotonic() - self._t0, 6),
                 "action": action}
        if slot is not None:
            entry["slot"] = slot
        entry.update(extra)
        with self._lock:
            self._log.append(entry)

    def log(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._log]

    def counts(self) -> dict[str, int]:
        """Per-action tallies of the log — the cheap CI assertion surface."""
        out: dict[str, int] = {}
        for e in self.log():
            out[e["action"]] = out.get(e["action"], 0) + 1
        return out


@dataclass
class SandboxInvocation:
    """What one trip through a sandbox produced (feeds InvocationRecord)."""
    blob: bytes
    stats: Any                         # EntryStats-shaped (attribute access)
    worker_id: int
    cold_start: bool
    server_s: float


class SandboxHost:
    """Cold/warm sandbox pool + fault injection around entry callables.

    Thread-safe; one host stands in for one fleet (client side) or for the
    sandboxes inside one worker process (worker side).  ``worker_id_base``
    keeps ids globally unique when several processes each run a host.
    """

    def __init__(self, fault_plan: FaultPlan | None = None, *,
                 worker_id_base: int = 0):
        self.fault_plan = fault_plan or FaultPlan()
        self._warm: dict[str, list[WorkerInstance]] = {}
        self._next_worker_id = worker_id_base
        self._live_instances = 0
        self._lock = threading.Lock()
        # fleet observability: cold/warm and busy-time accounting lives in
        # a PRIVATE metrics registry (several hosts per process in tests),
        # labeled by function — this registry replaced the ad-hoc
        # _cold_starts/_warm_hits/_busy_s/_per_fn dicts that used to live
        # here.  stats() keeps the legacy shape; the worker host's
        # /metrics and host_stats serve the registry directly.
        self.metrics = obs_metrics.Registry()
        self._m_cold = self.metrics.counter(
            "sandbox_cold_starts_total", "sandboxes provisioned cold")
        self._m_warm = self.metrics.counter(
            "sandbox_warm_hits_total", "invocations served by a warm sandbox")
        self._m_busy = self.metrics.counter(
            "entry_busy_seconds_total", "wall time inside entry callables")
        self._m_live = self.metrics.gauge(
            "sandbox_live_instances", "currently provisioned sandboxes")
        self._m_entry = self.metrics.histogram(
            "entry_seconds", "per-invocation entry wall time (s)",
            buckets=obs_metrics.DEFAULT_BUCKETS_S)
        self._fn_names: set[str] = set()

    # ----------------------------------------------------------- lifecycle
    def acquire(self, function_name: str) -> Tuple[WorkerInstance, bool]:
        """A sandbox for one invocation: warm if available, else cold."""
        with self._lock:
            self._fn_names.add(function_name)
            warm = self._warm.setdefault(function_name, [])
            if warm:
                self._m_warm.inc(function=function_name)
                return warm.pop(), False
            self._next_worker_id += 1
            self._live_instances += 1
            self._m_cold.inc(function=function_name)
            self._m_live.set(self._live_instances)
            return WorkerInstance(self._next_worker_id, function_name), True

    def release(self, inst: WorkerInstance) -> None:
        with self._lock:
            self._warm.setdefault(inst.function_name, []).append(inst)

    def discard(self, inst: WorkerInstance) -> None:
        """A crashed sandbox is never reused."""
        with self._lock:
            self._live_instances -= 1
            self._m_live.set(self._live_instances)

    def drain(self, function_name: str | None = None) -> int:
        """Scale-in: drop warm sandboxes (next invocations pay cold starts)."""
        with self._lock:
            if function_name is None:
                n = sum(len(v) for v in self._warm.values())
                self._warm.clear()
            else:
                n = len(self._warm.pop(function_name, []))
            self._live_instances -= n
            self._m_live.set(self._live_instances)
            return n

    @property
    def live_instances(self) -> int:
        with self._lock:
            return self._live_instances

    def warm_count(self, function_name: str | None = None) -> int:
        with self._lock:
            if function_name is None:
                return sum(len(v) for v in self._warm.values())
            return len(self._warm.get(function_name, []))

    def stats(self) -> dict:
        """Cold/warm and busy-time accounting, totals plus a per-function
        breakdown — what the fleet controller and ``Session.stats()`` read
        instead of scraping logs.  The shape predates the metrics registry
        and is preserved exactly; the numbers now come FROM the registry."""
        with self._lock:
            names = sorted(self._fn_names)
            live = self._live_instances
            warm = sum(len(v) for v in self._warm.values())
        return {"cold_starts": int(self._m_cold.total),
                "warm_hits": int(self._m_warm.total),
                "busy_s": self._m_busy.total,
                "live_instances": live,
                "warm_count": warm,
                "functions": {
                    name: {"cold_starts": int(self._m_cold.value(function=name)),
                           "warm_hits": int(self._m_warm.value(function=name)),
                           "busy_s": self._m_busy.value(function=name)}
                    for name in names}}

    # ------------------------------------------------------------- invoke
    def invoke(self, entry: Callable[[bytes], tuple], function_name: str,
               payload: bytes, *, task_id: int = 0,
               attempt: int = 1) -> SandboxInvocation:
        """One billed trip through a sandbox.

        Rolls the fault plan (an injected failure raises
        :class:`WorkerCrash` and burns the sandbox), times the entry call as
        the billable server duration, applies straggler inflation, and
        returns blob + stats + sandbox metadata.  User-code exceptions
        propagate unchanged — error policy belongs to the caller.
        """
        fail, straggle = self.fault_plan.roll(task_id, attempt)
        inst, cold = self.acquire(function_name)
        if fail:
            self.discard(inst)
            crash = WorkerCrash(
                f"sandbox {inst.worker_id} lost (task {task_id} "
                f"attempt {attempt})")
            self._stamp(crash, inst, cold)
            raise crash
        t0 = time.perf_counter()
        try:
            # stats come back with the blob: concurrent entries of the same
            # bridge must not read each other's accounting (shared-attr race)
            blob, stats = entry(payload)
            server_s = time.perf_counter() - t0
        except BaseException as e:
            self.discard(inst)       # errored sandbox is not re-warmed
            self._stamp(e, inst, cold)
            raise
        finally:
            # busy time is real wall clock inside the entry (straggler
            # inflation is billing, not occupancy), per slot and per host
            elapsed = time.perf_counter() - t0
            inst.busy_s += elapsed
            self._m_busy.inc(elapsed, function=function_name)
            self._m_entry.observe(elapsed, function=function_name)
        if straggle:
            if self.fault_plan.straggler_sleep_s:
                time.sleep(self.fault_plan.straggler_sleep_s)
            server_s *= self.fault_plan.straggler_factor
        inst.invocations += 1
        self.release(inst)
        return SandboxInvocation(blob=blob, stats=stats,
                                 worker_id=inst.worker_id, cold_start=cold,
                                 server_s=server_s)

    @staticmethod
    def _stamp(err: BaseException, inst: WorkerInstance, cold: bool) -> None:
        """Failure records must still say which sandbox burned: ride the
        accounting on the exception (some exception types reject attrs)."""
        try:
            err.sandbox_worker_id = inst.worker_id     # type: ignore[attr-defined]
            err.sandbox_cold_start = cold              # type: ignore[attr-defined]
        except Exception:
            pass
