"""repro.cloud — the single-source serverless API (see API.md).

    from repro import cloud

    with cloud.Session("threads") as sess:
        f = sess.function(my_fn, memory_mb=512)
        f(x)              # local call — the single-source property
        f.submit(x)       # one serverless invocation -> future
        f.map(items)      # ordered fork-join
        f.map_unordered(items)                  # streaming fork-join
        cloud.gather(futs, return_exceptions=True)
"""
from ..dispatch.backends import (Backend, BackendCapabilities,
                                 available_backends, register_backend,
                                 resolve_backend)
from ..dispatch.futures import (InvocationCancelled, InvocationFuture,
                                as_completed, gather)
from .session import (BoundFunction, Saturated, Session, session_for,
                      session_scope)

__all__ = [
    "Session", "BoundFunction", "session_for", "session_scope",
    "as_completed", "gather", "InvocationFuture", "InvocationCancelled",
    "Saturated", "Backend", "BackendCapabilities", "register_backend",
    "resolve_backend", "available_backends",
]
