"""``cloud.Session`` — the single-source serverless session (ISSUE 1).

The paper's promise is that one function object runs locally or in the
cloud with no per-backend code changes (Fig 1).  A ``Session`` is where
that promise lives: it owns a deployment, an execution backend (selected
by registry name — ``"threads"``, ``"inline"``, ``"sim-aws"``, …), and the
cost ledger, and it *binds* remote functions into handles::

    with cloud.Session("threads") as sess:

        @sess.remote(memory_mb=512)
        def square_sum(n):
            x = jnp.arange(n, dtype=jnp.float32)
            return jnp.sum(x * x)

        square_sum(8)                      # plain local call (single-source)
        fut = square_sum.submit(1_000)     # one serverless invocation
        outs = square_sum.map(range(8))    # ordered fork-join
        for r in square_sum.map_unordered(range(8)):
            ...                            # streaming, completion order
        big = square_sum.options(memory_mb=2048).submit(10_000_000)

    print(sess.cost.summary())             # GB-seconds, $, cold starts

Switching ``"threads"`` → ``"inline"`` → ``"sim-aws"`` touches only the
``Session(...)`` line — never the functions, never the call sites.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Iterable, Iterator, Sequence

from ..core.config import FunctionConfig
from ..core.deploy import Deployment
from ..core.function import RemoteFunction
from ..dispatch.backends import Backend
from ..dispatch.dispatcher import Dispatcher, DispatcherInstance
from ..dispatch.futures import InvocationFuture, as_completed
from ..dispatch.latency_model import DEFAULT_LATENCY, LatencyModel
from ..dispatch.workers import FaultPlan
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace

_CONFIG_FIELDS = frozenset(f.name for f in dataclasses.fields(FunctionConfig))


class Saturated(RuntimeError):
    """Admission control: the session is at ``max_concurrency`` and was
    asked to shed (``Session(..., shed=True)``) rather than queue."""


def _override(cfg: FunctionConfig, overrides: dict) -> FunctionConfig:
    unknown = set(overrides) - _CONFIG_FIELDS
    if unknown:
        raise TypeError(
            f"unknown function option(s) {sorted(unknown)}; "
            f"valid: {sorted(_CONFIG_FIELDS)}")
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def _as_args(item: Any) -> tuple:
    """``map`` items may be pre-built argument tuples or single arguments."""
    return item if isinstance(item, tuple) else (item,)


class BoundFunction:
    """A remote function bound to a session — the Ray-style handle.

    Carries its own resolved :class:`FunctionConfig`; ``options()`` returns
    a derived handle, so override precedence is naturally
    *call (latest ``options``) > handle > function config*.
    """

    def __init__(self, session: "Session", rf: RemoteFunction,
                 config: FunctionConfig):
        self._session = session
        self._rf = rf
        self.config = config

    @property
    def name(self) -> str:
        return self._rf.human_name

    # -- single-source: the local call path is untouched --------------------
    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        return self._rf.fn(*args, **kwargs)

    # -- per-call overrides --------------------------------------------------
    def options(self, **overrides: Any) -> "BoundFunction":
        """Chainable per-call overrides: ``f.options(memory_mb=512,
        serializer="binary_json").submit(x)``.  Any ``FunctionConfig``
        field is accepted; later calls win."""
        return BoundFunction(self._session, self._rf,
                             _override(self.config, overrides))

    # -- remote invocation ---------------------------------------------------
    def submit(self, *args: Any, **kwargs: Any) -> InvocationFuture:
        """Fire one serverless invocation; returns a future."""
        return self._session.dispatch(self._rf, *args,
                                      config=self.config, **kwargs)

    def map(self, items: Iterable[Any], *,
            hedge_quantile: float | None = None) -> list[Any]:
        """Ordered fork-join over ``items`` (each an args-tuple or a single
        argument), with optional straggler hedging."""
        arglists = [_as_args(i) for i in items]
        return self._session.map(self._rf, arglists, config=self.config,
                                 hedge_quantile=hedge_quantile)

    def map_unordered(self, items: Iterable[Any], *,
                      timeout: float | None = None) -> Iterator[Any]:
        """Streaming fork-join: yield results in *completion* order.

        Replaces the blocking ordered-only map when the reduction is
        order-independent — consumers start folding while stragglers run.
        Tasks are submitted eagerly (the fork happens at the call, like
        ``submit``/``map``); only the result drain is lazy.
        """
        futs = [self.submit(*_as_args(i)) for i in items]

        def drain():
            for fut in as_completed(futs, timeout=timeout):
                yield fut.result()
        return drain()

    def __repr__(self) -> str:
        return (f"BoundFunction({self.name!r}, "
                f"backend={type(self._session.backend).__name__}, "
                f"memory_mb={self.config.memory_mb})")


class Session:
    """One serverless 'cloud' — deployment + backend + cost accounting.

    Context manager; on exit the backend is shut down — unless the session
    wraps a caller-owned resource (an existing ``Dispatcher`` or a live
    ``Backend`` instance, both possibly shared across sessions), which the
    caller keeps responsibility for.  A session is also an invocation
    namespace: everything submitted through it lands in ``session.cost`` /
    ``session.records``.
    """

    def __init__(self, backend: str | Backend = "threads", *,
                 deployment: Deployment | None = None,
                 client: str = "http2_pool",
                 latency: LatencyModel = DEFAULT_LATENCY,
                 max_concurrency: int = 1000, os_threads: int = 16,
                 fault_plan: FaultPlan | None = None,
                 chaos: "Any | None" = None,
                 retry: "Any | None" = None,
                 manifest_path: str | None = None,
                 shed: bool = False,
                 dispatcher: Dispatcher | None = None,
                 trace_sample: float | None = None,
                 obs_enabled: bool | None = None,
                 strict_analysis: bool = False):
        # observability knobs land on the PROCESS tracer (one trace plane
        # per process, like the metrics registry) — last session to set
        # them wins.  trace_sample=1.0 records every request's span tree;
        # the default (sample 0, disabled) keeps every instrumentation
        # site on its few-ns attribute-check path.
        if trace_sample is not None or obs_enabled is not None:
            kw: dict = {}
            if trace_sample is not None:
                kw["sample"] = trace_sample
            if obs_enabled is not None:
                kw["enabled"] = obs_enabled
            obs_trace.configure(**kw)
        self._shed = shed
        self._admission_lock = threading.Lock()
        self._admitted = 0            # shed-mode reservations not yet resolved
        if dispatcher is not None:
            self._dispatcher = dispatcher
            self._owns_dispatcher = False
            if strict_analysis:   # opt-in is sticky on the shared deployment
                dispatcher.deployment.strict_analysis = True
        else:
            self._dispatcher = Dispatcher(
                backend=backend, deployment=deployment, client=client,
                latency=latency, max_concurrency=max_concurrency,
                os_threads=os_threads, fault_plan=fault_plan,
                chaos=chaos, retry=retry,
                manifest_path=manifest_path,
                strict_analysis=strict_analysis)
            # a live Backend instance is caller-owned (it may be shared
            # across sessions); names/classes/factories produce one for us
            self._owns_dispatcher = (
                isinstance(backend, (str, type))
                or not isinstance(backend, Backend))
        self._inst: DispatcherInstance = self._dispatcher.create_instance()
        self._closed = False

    @classmethod
    def from_dispatcher(cls, dispatcher: Dispatcher) -> "Session":
        """Wrap an existing dispatcher (shared fleet, caller-owned)."""
        return cls(dispatcher=dispatcher)

    # ------------------------------------------------------------- binding
    def function(self, fn: Callable | RemoteFunction, *,
                 name: str | None = None, jax_traceable: bool | None = None,
                 **overrides: Any) -> BoundFunction:
        """Bind ``fn`` to this session; keyword overrides are
        ``FunctionConfig`` fields (handle-level config)."""
        if isinstance(fn, RemoteFunction):
            if name is not None or jax_traceable is not None:
                raise TypeError(
                    "name/jax_traceable are fixed on an existing "
                    "RemoteFunction; set them at RemoteFunction creation")
            rf = fn
        else:
            rf = RemoteFunction(
                fn, name=name,
                jax_traceable=True if jax_traceable is None else jax_traceable)
        return BoundFunction(self, rf, _override(rf.config, overrides))

    def remote(self, fn: Callable | None = None, *, name: str | None = None,
               jax_traceable: bool | None = None, **overrides: Any):
        """Decorator form: ``@sess.remote`` or
        ``@sess.remote(memory_mb=512, serializer="binary")``."""
        def wrap(f):
            return self.function(f, name=name, jax_traceable=jax_traceable,
                                 **overrides)
        return wrap(fn) if fn is not None else wrap

    # ----------------------------------------------- paper-style namespace
    # (these make a Session a drop-in invocation namespace for the
    #  paper-style ``dispatch(x, fn)`` / ``wait(x, n)`` module shim)
    def dispatch(self, fn, *args: Any, config: FunctionConfig | None = None,
                 **kwargs: Any) -> InvocationFuture:
        if self._closed:
            raise RuntimeError("session is closed; submissions would never "
                               "complete on a shut-down backend")
        reserved = self._reserve(1)
        try:
            fut = self._inst.dispatch(fn, *args, config=config, **kwargs)
        except BaseException:
            if reserved:
                self._release(1)
            raise
        if reserved:
            fut.add_done_callback(lambda _f: self._release(1))
        return fut

    def map(self, fn, arglists: Sequence[tuple],
            config: FunctionConfig | None = None,
            hedge_quantile: float | None = None) -> list[Any]:
        if self._closed:
            raise RuntimeError("session is closed; submissions would never "
                               "complete on a shut-down backend")
        reserved = self._reserve(len(arglists))
        try:
            futs, cfg = self._inst.map_futures(
                fn, arglists, config=config, hedge_quantile=hedge_quantile)
        except BaseException:
            if reserved:
                self._release(len(arglists))
            raise
        if reserved:
            # each slot frees when ITS task resolves — a failed sibling must
            # not release slots for tasks still in flight
            for f in futs:
                f.add_done_callback(lambda _f: self._release(1))
        return [f.result(timeout=cfg.timeout_s) for f in futs]

    def wait(self, n: int | None = None, timeout: float = 300.0) -> None:
        self._inst.wait(n, timeout=timeout)

    # --------------------------------------------------- admission control
    @property
    def inflight(self) -> int:
        """Invocations submitted through this session and not yet resolved."""
        return self._inst.inflight

    @property
    def queue_depth(self) -> int:
        """Invocations the backend has accepted but not yet started."""
        return getattr(self.backend, "queue_depth", 0)

    def _reserve(self, n: int) -> bool:
        """Shed-mode gate: atomically reserve ``n`` admission slots or raise
        :class:`Saturated` (ROADMAP: admission control).  A reservation
        counter — not a read of ``inflight`` — so concurrent submitters
        cannot race past ``max_concurrency`` between check and dispatch."""
        if not self._shed:
            return False
        limit = self._dispatcher.max_concurrency
        with self._admission_lock:
            if self._admitted + n > limit:
                raise Saturated(
                    f"session at max_concurrency={limit} "
                    f"({self._admitted} admitted, +{n} requested); "
                    f"shed=True rejects instead of queueing")
            self._admitted += n
        return True

    def _release(self, n: int) -> None:
        with self._admission_lock:
            self._admitted -= n

    # ------------------------------------------------------------ plumbing
    @property
    def dispatcher(self) -> Dispatcher:
        return self._dispatcher

    @property
    def backend(self) -> Backend:
        return self._dispatcher.backend

    @property
    def deployment(self) -> Deployment:
        return self._dispatcher.deployment

    # ---------------------------------------------------------- accounting
    @property
    def cost(self):
        return self._inst.cost

    @property
    def records(self):
        return self._inst.records

    @property
    def chaos(self):
        """The session's :class:`~repro.runtime.sandbox.ChaosPlan` (None
        unless chaos injection was requested at construction)."""
        return self._dispatcher.chaos

    @property
    def retry_log(self) -> list[dict]:
        """Every backed-off resubmission this session scheduled:
        ``{task_id, attempt, t, backoff_s}`` — chaos tests assert the
        timestamps are exponentially spaced (ISSUE 10)."""
        return self._inst.retry_log

    def stats(self) -> dict:
        """Fleet state without log-scraping (ISSUE 6): cold/warm start
        counters, per-slot busy time and resident-state leases, aggregated
        from the backend (one CONTROL round-trip per spawned worker on
        out-of-process backends — cheap, but not free; poll accordingly).
        Always includes ``inflight``/``queue_depth``; backends without
        accounting report just those."""
        out: dict = {"backend": type(self.backend).__name__,
                     "inflight": self.inflight,
                     "queue_depth": self.queue_depth}
        bstats = getattr(self.backend, "stats", None)
        if callable(bstats):
            try:
                out.update(bstats())
            except Exception as e:     # a dead fleet still reports the rest
                out["error"] = str(e) or type(e).__name__
        if "metrics" not in out:
            # in-process backends have no worker fleet to aggregate from:
            # the process-default registry plus the pool's sandbox registry
            # IS the whole metrics plane
            merged = obs_metrics.Registry()
            merged.merge(obs_metrics.REGISTRY.snapshot())
            sb = getattr(self.backend, "sandboxes", None)
            if sb is not None:
                merged.merge(sb.metrics.snapshot())
            out["metrics"] = merged.snapshot()
        return out

    def dump_trace(self, path: str) -> int:
        """Write every span recorded this process (client-side plus the
        worker-side spans shipped back on reply envelopes) as Chrome-trace
        JSON — open in ``chrome://tracing`` / Perfetto.  Returns the event
        count.  Needs ``trace_sample > 0`` (or ``obs.configure``) to have
        recorded anything."""
        return obs_trace.TRACER.dump(path)

    def modeled_latencies_ms(self) -> list[float]:
        return self._inst.modeled_latencies_ms()

    def modeled_makespan_ms(self) -> float:
        return self._inst.modeled_makespan_ms()

    # ------------------------------------------------------------ lifetime
    def close(self) -> None:
        if not self._closed and self._owns_dispatcher:
            self._dispatcher.shutdown()
        self._closed = True

    shutdown = close

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"Session(backend={type(self.backend).__name__}, "
                f"invocations={self.cost.invocations}, "
                f"closed={self._closed})")


def session_for(session: Session | None = None,
                dispatcher: Dispatcher | None = None,
                backend: str | Backend = "threads") -> Session:
    """Resolve the session an app-level helper should run in.

    Accepts an explicit session, a legacy dispatcher (wrapped), or neither
    (fresh session on ``backend``) — keeps ``compute_pi``-style helpers
    source-compatible across both API generations.
    """
    if session is not None:
        return session
    if dispatcher is not None:
        return Session.from_dispatcher(dispatcher)
    return Session(backend)


@contextlib.contextmanager
def session_scope(session: Session | None = None,
                  dispatcher: Dispatcher | None = None,
                  backend: str | Backend = "threads"):
    """``session_for`` with helper-side ownership: a session the helper
    created itself is closed on exit (even on error; cost/records stay
    readable afterwards), while a caller-provided session/dispatcher is
    left untouched."""
    sess = session_for(session, dispatcher, backend)
    owned = session is None and dispatcher is None
    try:
        yield sess
    finally:
        if owned:
            sess.close()
