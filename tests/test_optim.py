"""Optimizer substrate: AdamW, schedule, clipping, int8 error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.optim import (AdamW, clip_by_global_norm, compress_int8,
                         cosine_schedule, decompress_int8, global_norm)


def test_adamw_converges_quadratic():
    opt = AdamW(peak_lr=0.1, warmup=5, total_steps=200, weight_decay=0.0,
                clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        return opt.update(grads, state, params)

    for _ in range(200):
        params, state, m = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup=10,
                                 total=100)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # peak at end of warmup
    assert lrs[-1] < lrs[1]                   # decays
    assert lrs[-1] >= 0.099                   # floor


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.01, 100.0))
def test_int8_roundtrip_error_bounded(scale):
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(64,)) * scale, jnp.float32)
    q, s, err = compress_int8(g, jnp.zeros_like(g))
    deq = decompress_int8(q, s)
    # quantization error bounded by half a step
    assert float(jnp.max(jnp.abs(deq + err - g))) < 1e-5
    assert float(jnp.max(jnp.abs(err))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_recovers_signal():
    """With error feedback, the *sum* of dequantized grads tracks the sum
    of true grads (bias-free compression over steps)."""
    rng = np.random.default_rng(2)
    err = jnp.zeros((32,), jnp.float32)
    total_true = np.zeros(32, np.float32)
    total_deq = np.zeros(32, np.float32)
    for i in range(50):
        g = jnp.asarray(rng.normal(size=(32,)) * 0.01, jnp.float32)
        q, s, err = compress_int8(g, err)
        total_true += np.asarray(g)
        total_deq += np.asarray(decompress_int8(q, s))
    resid = np.abs(total_deq + np.asarray(err) - total_true).max()
    assert resid < 1e-4
