"""Async serving subsystem (ISSUE 3): AsyncSession across every registered
backend (await submit / async-for map_unordered / cancellation / awaitable
admission gate), the thread-safe future-callback contract underneath it,
the continuous batcher, the artifact store, and the serve bench's schema.
"""
import asyncio
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cloud import Session
from repro.dispatch.futures import (InvocationCancelled, InvocationFuture,
                                    InvocationRecord)
from repro.serving import AsyncSession, ContinuousBatcher, run_continuous

# ----------------------------------------------------------- the matrix ----
# The acceptance matrix: the async surface must behave identically on every
# registered backend, including the real out-of-process transports.  Task
# functions live at module level so `processes`/`http` can ship them by
# reference.

MATRIX_BACKENDS = ("inline", "threads", "sim-aws", "processes", "http",
                   "http-aio")


def aio_square_sum(x):
    import jax.numpy as jnp
    return jnp.sum(x * x)


def aio_sleepy(s):
    import time
    time.sleep(s)
    return s


@pytest.fixture(scope="module", params=MATRIX_BACKENDS)
def sync_session(request):
    with Session(request.param, os_threads=2) as sess:
        yield sess


def test_matrix_await_submit(sync_session):
    async def go():
        asess = AsyncSession(sync_session)
        f = asess.function(aio_square_sum, name="aio_ssq", memory_mb=512)
        inv = f.submit(jnp.ones(4))
        out = await inv
        assert float(out) == 4.0
        assert inv.record is not None and inv.record.memory_gb == 0.5
    asyncio.run(go())


def test_matrix_async_for_map_unordered(sync_session):
    async def go():
        asess = AsyncSession(sync_session)
        f = asess.function(aio_square_sum, name="aio_ssq")
        seen = []
        async for r in f.map_unordered([(jnp.ones(4) * i,)
                                        for i in range(4)]):
            seen.append(float(r))
        assert sorted(seen) == [0.0, 4.0, 16.0, 36.0]
    asyncio.run(go())


def test_matrix_cancellation(sync_session):
    """Cancelling an AsyncInvocation cancels the backend future: queued
    work sheds, siblings are untouched, the gate fully drains."""
    async def go():
        asess = AsyncSession(sync_session, max_inflight=2)
        f = asess.function(aio_sleepy, jax_traceable=False)
        siblings = [f.submit(0.2) for _ in range(2)]
        victim = f.submit(0.2)         # parked at the admission gate
        await asyncio.sleep(0)
        if victim.cancel():
            with pytest.raises(asyncio.CancelledError):
                await victim
        assert [await s for s in siblings] == [0.2, 0.2]
        # the gate must be fully released afterwards
        assert float(await f.submit(0.01)) == 0.01
        assert asess.admitted == 0
    asyncio.run(go())


def test_matrix_admission_gate_parks_then_releases(sync_session):
    """The awaitable gate: the N+1th submit waits for a completion instead
    of raising Saturated — and proceeds once inflight drains."""
    async def go():
        asess = AsyncSession(sync_session, max_inflight=2)
        f = asess.function(aio_sleepy, jax_traceable=False)
        t0 = time.perf_counter()
        invs = [f.submit(0.3) for _ in range(2)]
        third = f.submit(0.05)
        await asyncio.sleep(0.1)
        assert asess.admitted == 2     # gate holds exactly the limit
        assert asess.waiting >= 1      # the third is parked, not rejected
        assert float(await third) == 0.05
        # it could only run after a slot freed → a 0.3 s sleep finished
        assert time.perf_counter() - t0 >= 0.25
        await asyncio.gather(*invs)
        assert asess.admitted == 0
    asyncio.run(go())


def test_matrix_admit_release_are_manual_too(sync_session):
    async def go():
        asess = AsyncSession(sync_session, max_inflight=1)
        await asess.admit()
        assert asess.admitted == 1
        waiter = asyncio.get_running_loop().create_task(asess.admit())
        await asyncio.sleep(0.05)
        assert not waiter.done()       # parked behind the held slot
        asess.release()
        await waiter
        assert asess.admitted == 1     # the slot changed hands
        asess.release()
        assert asess.admitted == 0
    asyncio.run(go())


# --------------------------------------------- future callback contract ----

def test_add_done_callback_fires_exactly_once_across_threads():
    fut = InvocationFuture(0)
    fired: list[int] = []
    barrier = threading.Barrier(9)

    def register(i):
        barrier.wait()
        fut.add_done_callback(lambda _f, i=i: fired.append(i))

    def complete():
        barrier.wait()
        fut.set_result(42, InvocationRecord(0, "f"))

    threads = [threading.Thread(target=register, args=(i,)) for i in range(8)]
    threads.append(threading.Thread(target=complete))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(fired) == list(range(8))     # all fired, exactly once
    fut.add_done_callback(lambda _f: fired.append(99))
    assert fired[-1] == 99                     # already-done → immediate


def test_future_cancel_contract():
    fut = InvocationFuture(1)
    assert fut.cancel()
    assert fut.done() and fut.cancelled()
    with pytest.raises(InvocationCancelled):
        fut.result(timeout=0)
    assert fut.exception(timeout=0).__class__ is InvocationCancelled
    # completion after cancel loses the race
    assert not fut.set_result(1, InvocationRecord(1, "f"))
    # cancel after completion loses too
    fut2 = InvocationFuture(2)
    fut2.set_result(1, InvocationRecord(2, "f"))
    assert not fut2.cancel()


def test_gather_treats_cancellation_as_settled_failure():
    """InvocationCancelled is a CancelledError (BaseException) but it is a
    *settled* per-task outcome: gather's partial-failure policy must slot
    it under return_exceptions instead of letting it escape."""
    from repro.cloud import gather
    with Session("threads", os_threads=1) as sess:
        f = sess.function(aio_sleepy, jax_traceable=False)
        ok = f.submit(0.1)
        victim = f.submit(0.1)         # queued behind the single thread
        assert victim.cancel()
        out = gather([ok, victim], return_exceptions=True, timeout=30)
        assert out[0] == 0.1
        assert isinstance(out[1], InvocationCancelled)
        with pytest.raises(InvocationCancelled):
            gather([f.submit(0.01), victim], timeout=30)


def test_cancelled_future_does_not_leak_session_inflight():
    """Backends skip a done future; the dispatcher's pending set must still
    shrink — wait() returns and inflight drops to zero."""
    with Session("threads", os_threads=1) as sess:
        f = sess.function(aio_sleepy, jax_traceable=False)
        blocker = f.submit(0.3)
        queued = f.submit(0.3)         # behind the single thread
        assert queued.cancel()
        sess.wait(timeout=30)
        assert sess.inflight == 0
        assert blocker.result(timeout=30) == 0.3
        with pytest.raises(InvocationCancelled):
            queued.result(timeout=0)


# ------------------------------------------------------------- batching ----

@pytest.fixture(scope="module")
def lm_setup():
    import jax
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _mixed_requests(cfg, n=6, prompt_len=8):
    from repro.runtime.server import Request
    rng = np.random.default_rng(0)
    return [Request(prompt=list(rng.integers(1, cfg.vocab_size, prompt_len)),
                    max_new=(4 if i % 2 else 8)) for i in range(n)]


def test_continuous_batching_matches_waves(lm_setup):
    """Same pack/unpack core ⇒ identical greedy tokens, wave or continuous,
    with mixed decode lengths (bucketing trims, never truncates).  Ragged
    prompt sets are covered by the composition-invariance matrix below —
    packing is pad-masked end to end."""
    from repro.runtime.server import LMServer

    cfg, params = lm_setup
    with Session("threads", os_threads=2) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = _mixed_requests(cfg)
        wave = server.serve(reqs, wave_size=3)
        cont = run_continuous(server, reqs, concurrency=6, max_batch=3,
                              slots=2, max_wait_ms=5)
        assert [c.tokens for c in wave] == [c.tokens for c in cont]
        assert [len(c.tokens) for c in cont] == [8, 4, 8, 4, 8, 4]


def test_batcher_stats_and_bucketing(lm_setup):
    """The batch-level scheduler's internals (bucketing, seal stats) —
    pinned to iteration_level=False, since on a resident-state backend the
    batcher would otherwise upgrade to the iteration-level path (whose
    stats are covered in test_engine.py)."""
    from repro.runtime.server import LMServer

    cfg, params = lm_setup
    with Session("threads", os_threads=2) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = _mixed_requests(cfg, n=8)

        async def go():
            async with ContinuousBatcher(server, max_batch=4, slots=2,
                                         max_wait_ms=5,
                                         iteration_level=False) as b:
                comps = await asyncio.gather(*[b.submit(r) for r in reqs])
                return comps, b.stats
        comps, stats = asyncio.run(go())
        assert len(comps) == 8
        assert stats.requests == 8
        assert stats.mode == "batch"
        assert stats.batches >= 2
        # like-length grouping happened: both decode buckets appear
        assert set(stats.bucket_histogram) == {4, 8}


def test_batcher_cancelled_request_is_skipped(lm_setup):
    from repro.runtime.server import LMServer

    cfg, params = lm_setup
    with Session("threads", os_threads=2) as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        reqs = _mixed_requests(cfg, n=3)

        async def go():
            async with ContinuousBatcher(server, max_batch=4, slots=1,
                                         max_wait_ms=50) as b:
                t1 = asyncio.ensure_future(b.submit(reqs[0]))
                t2 = asyncio.ensure_future(b.submit(reqs[1]))
                await asyncio.sleep(0)
                t2.cancel()                  # cancelled while queued
                out = await t1
                with pytest.raises(asyncio.CancelledError):
                    await t2
                return out, b.stats
        out, stats = asyncio.run(go())
        assert len(out.tokens) == reqs[0].max_new
        assert stats.requests < 3            # the cancelled one never packed


# ------------------- batch-composition invariance (continuous batching) ----
# Wave-mode invariance lives in test_apps_server.py; this is the same
# property under slot-based admission: whatever batches the scheduler
# happens to seal (bucketed, topped-up, min_rows-padded with fully masked
# filler rows), each request's greedy tokens equal its solo run.

@pytest.mark.parametrize("backend", ("inline", "processes"))
def test_continuous_ragged_batch_is_composition_invariant(lm_family,
                                                          backend):
    from conftest import make_ragged_requests, solo_reference
    from repro.runtime.server import LMServer

    _, cfg, params = lm_family
    with Session(backend, os_threads=1) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = make_ragged_requests(cfg)
        solo = solo_reference(server, reqs)
        comps = run_continuous(server, reqs, concurrency=4, max_batch=4,
                               slots=2, max_wait_ms=5)
        assert [c.tokens for c in comps] == solo
        server.close(prune=False)


# ------------------------------------------------------- artifact store ----

def test_artifact_gc_spares_live_and_kept_refs(tmp_path):
    """prune_artifacts unlinks only blobs that are neither live in this
    process nor explicitly kept."""
    from repro.serialization import (load_artifact, prune_artifacts,
                                     put_artifact, release_artifact)
    d = str(tmp_path)
    live = put_artifact({"a": np.arange(3)}, directory=d)
    kept = put_artifact({"b": np.arange(4)}, directory=d)
    dead = put_artifact({"c": np.arange(5)}, directory=d)
    release_artifact(kept)
    release_artifact(dead)
    removed = prune_artifacts(keep=[kept], directory=d)
    assert removed == [dead.path]
    assert os.path.exists(live.path) and os.path.exists(kept.path)
    np.testing.assert_array_equal(load_artifact(kept)["b"], np.arange(4))
    release_artifact(live)                       # leave no live claims behind
    assert sorted(prune_artifacts(directory=d)) == sorted(
        [live.path, kept.path])


def test_lmserver_close_prunes_own_params_not_anothers(lm_setup):
    """LMServer teardown GCs the store: the closed server's params blob is
    unlinked, a still-open server's blob survives and keeps serving."""
    from conftest import make_ragged_requests
    from repro.runtime.server import LMServer

    cfg, _ = lm_setup
    import jax
    from repro.models import build_model
    # params unique to this test: other tests hold live claims on the
    # shared lm_setup params (same content => same blob), which close()
    # must — and does — refuse to reap
    params1, _ = build_model(cfg).init(jax.random.PRNGKey(2))
    params2, _ = build_model(cfg).init(jax.random.PRNGKey(1))
    with Session("inline") as sess:
        s1 = LMServer(cfg, params1, session=sess, max_new=4)
        s2 = LMServer(cfg, params2, session=sess, max_new=4)
        p1, p2 = s1._params_ref.path, s2._params_ref.path
        assert p1 != p2                          # distinct content, two blobs
        s1.close()
        assert not os.path.exists(p1)            # own blob reaped
        assert os.path.exists(p2)                # live neighbour survives
        reqs = make_ragged_requests(cfg)[:2]
        assert len(s2.serve_wave(reqs)) == 2     # ...and still serves
        with pytest.raises(RuntimeError, match="closed"):
            s1.submit_wave(reqs)
        s2.close(prune=False)


def test_artifact_refs_resolve_across_processes(lm_setup):
    """Params deploy once (content-addressed); payloads carry the pointer
    and real worker processes resolve + cache it — tokens identical to the
    in-process run, payloads orders of magnitude smaller."""
    from repro.runtime.server import LMServer

    cfg, params = lm_setup
    reqs = _mixed_requests(cfg, n=4)
    with Session("threads", os_threads=2) as s1:
        ref = LMServer(cfg, params, session=s1, max_new=4).serve(
            reqs, wave_size=2)
        assert all(r.payload_bytes < 64_000 for r in s1.records)
    with Session("processes", os_threads=2) as s2:
        out = LMServer(cfg, params, session=s2, max_new=4).serve(
            reqs, wave_size=2)
    assert [c.tokens for c in ref] == [c.tokens for c in out]


def test_artifact_roundtrip_and_integrity(tmp_path):
    from repro.serialization import (ArtifactRef, load_artifact,
                                     put_artifact, serialize)
    value = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    ref = put_artifact(value, directory=str(tmp_path))
    ref2 = put_artifact(value, directory=str(tmp_path))
    assert ref == ref2                           # content-addressed
    np.testing.assert_array_equal(load_artifact(ref)["w"], value["w"])
    # corrupt store file + cold cache → loud failure
    with open(ref.path, "wb") as f:
        f.write(serialize({"w": np.zeros((2, 3), np.float32)}))
    stale = ArtifactRef(path=ref.path, sha="0" * 64)
    with pytest.raises(ValueError, match="hash"):
        load_artifact(stale)


# ------------------------------------------------------------ the bench ----

def test_serve_bench_schema_smoke():
    """The CI-facing contract: serve_bench runs end to end on the threads
    backend and emits the repro.serve_bench/v1 document."""
    import benchmarks.serve_bench as sb

    doc = sb.run("threads", requests=8, concurrency=8, prompt_len=8,
                 max_new=4, wave=4, slots=2, os_threads=2,
                 prefix_shared=0.5,
                 modes=("waves", "continuous-batch", "continuous"))
    assert doc["schema"] == "repro.serve_bench/v2"
    for mode in ("waves", "continuous-batch", "continuous"):
        r = doc["results"][mode]
        assert r["requests"] == 8
        for k in ("throughput_rps", "tokens_per_s", "p50_ms", "p95_ms",
                  "p99_ms", "wall_s", "ttft_p50_ms", "tpot_p50_ms"):
            assert k in r, (mode, k)
    assert "speedup_continuous_vs_waves" in doc
    assert "speedup_iteration_vs_batch" in doc
    assert doc["results"]["continuous"]["scheduler"]["requests"] == 8
    assert doc["results"]["continuous"]["scheduler"]["mode"] == "iteration"
    assert doc["results"]["continuous"]["scheduler"]["prefix_hits"] >= 1
    assert doc["results"]["continuous-batch"]["scheduler"]["mode"] == "batch"
