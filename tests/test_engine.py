"""Iteration-level serving engine (ISSUE 5): slot-arena primitives, the
worker-resident state registry (leases, TTL reclaim), the prompt-prefix
cache, worker pinning, and the composition-invariance matrix — tokens
from iteration-level admission (prefix hits included) must be
bit-identical to solo wave decode, every family, inline and processes."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ragged_requests, solo_reference
from repro.cloud import Session
from repro.runtime import state
from repro.runtime.engine import EngineClient, is_state_lost, prefix_key
from repro.runtime.server import LMServer, Request
from repro.serving import ContinuousBatcher, run_continuous


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(autouse=True)
def _clean_state_registry():
    yield
    for h in list(state.stats()["handles"]):
        state.release(h)


# ------------------------------------------------------- state registry ----

def test_state_lease_create_touch_release():
    made = []
    data = state.lease("h1", ttl_s=30.0, make=lambda: made.append(1) or
                       {"x": 1})
    assert data == {"x": 1} and made == [1]
    # second lease returns the same dict, does not rebuild
    assert state.lease("h1", ttl_s=30.0, make=lambda: {"x": 2})["x"] == 1
    assert state.get("h1")["x"] == 1
    assert state.release("h1") is True
    assert state.release("h1") is False          # idempotent
    with pytest.raises(KeyError, match="state handle"):
        state.get("h1")


def test_state_ttl_reclaims_expired_leases(monkeypatch):
    clock = [100.0]
    monkeypatch.setattr(state, "_now", lambda: clock[0])
    state.lease("short", ttl_s=5.0, make=dict)
    state.lease("long", ttl_s=500.0, make=dict)
    clock[0] += 10.0                             # short expires, long lives
    assert state.sweep() == ["short"]
    assert state.stats()["handles"] == ["long"]
    with pytest.raises(KeyError, match="state handle"):
        state.get("short")
    # touching renews: long survives another near-expiry window
    clock[0] += 490.0
    state.get("long")
    clock[0] += 490.0
    assert state.stats()["handles"] == ["long"]


def test_state_control_verbs():
    state.lease("c1", ttl_s=60.0, make=dict)
    assert state.control("state_lease", {"handle": "c1"}) == \
        {"ok": True, "known": True}
    assert state.control("state_lease", {"handle": "nope"}) == \
        {"ok": True, "known": False}
    assert state.control("state_stats", {})["count"] >= 1
    assert state.control("state_release", {"handle": "c1"})["released"]
    with pytest.raises(ValueError, match="unknown state op"):
        state.control("state_nuke", {})


# --------------------------------------------------------- prefix hashing ----

def test_prefix_key_no_collision_on_pad_id_prompts():
    """[pad, x, y] and [x, y] pack to identical left-padded rows; the
    prefix key hashes the raw tokens + length, so they must differ."""
    pad = 0
    a = [pad, 7, 9]
    b = [7, 9]
    assert prefix_key(a) != prefix_key(b)
    assert prefix_key([pad, pad, 3]) != prefix_key([pad, 3]) != \
        prefix_key([3])
    assert prefix_key(a) == prefix_key(list(a))  # deterministic


# ------------------------------------------------------ arena primitives ----

def test_arena_insert_extract_free_roundtrip(lm_setup):
    """Inserting a prefilled row into an arena slot reproduces exactly the
    row's cache content at the cursor-aligned offset, and freeing masks
    the row (start jumps to the cursor)."""
    from repro.models import build_model
    from repro.models.api import (arena_init_cache, cache_extract_rows,
                                  cache_free_rows, cache_insert_rows)
    from repro.runtime.server import pack_prompts

    cfg, params = lm_setup
    model = build_model(cfg)
    prompts = [[5, 6, 7], [1, 2, 3, 4, 5]]
    tokens, lengths = pack_prompts(prompts, pad=cfg.pad_id)
    _, cache = model.prefill(params, {"tokens": jnp.asarray(tokens),
                                      "lengths": jnp.asarray(lengths)})
    width = tokens.shape[1]
    cursor = 16
    arena = arena_init_cache(cfg, batch=4, cap=64, cursor=cursor)
    rows = cache_extract_rows(cfg, cache, (0, 1))
    arena = cache_insert_rows(cfg, arena, rows, (2, 0), lengths[:2],
                              width=width)
    # start = cursor - length, per inserted slot
    assert int(arena["start"][2]) == cursor - 3
    assert int(arena["start"][0]) == cursor - 5
    assert int(arena["start"][1]) == cursor          # untouched: fully masked
    # content: the row's keys land so its last token sits at cursor-1
    np.testing.assert_array_equal(
        np.asarray(arena["k"][:, 2, cursor - width:cursor]),
        np.asarray(cache["k"][:, 0]))
    freed = cache_free_rows(cfg, arena, (2,))
    assert int(freed["start"][2]) == int(arena["idx"])


def test_arena_insert_rejects_overwide_rows(lm_setup):
    from repro.models import build_model
    from repro.models.api import (arena_init_cache, cache_extract_rows,
                                  cache_insert_rows)

    cfg, params = lm_setup
    model = build_model(cfg)
    toks = jnp.asarray(np.arange(1, 33, dtype=np.int32)[None, :])
    _, cache = model.prefill(params, {"tokens": toks,
                                      "lengths": jnp.asarray([32])})
    arena = arena_init_cache(cfg, batch=2, cap=64, cursor=16)
    rows = cache_extract_rows(cfg, cache, (0,))
    with pytest.raises(ValueError, match="cursor"):
        cache_insert_rows(cfg, arena, rows, (0,), (32,), width=32)


def test_grow_cache_rounds_to_pow2_bucket(lm_setup):
    from repro.models import build_model
    from repro.models.api import grow_cache

    cfg, params = lm_setup
    model = build_model(cfg)
    toks = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]])
    _, cache = model.prefill(params, {"tokens": toks,
                                      "lengths": jnp.asarray([8])})
    grown = grow_cache(cfg, cache, 8 + 3)        # exact fit would be 11
    assert grown["k"].shape[2] == 16             # pow2 bucket
    assert grow_cache(cfg, cache, 11, bucket=False)["k"].shape[2] == 11


# -------------------------------------------------------- engine client ----

def test_engine_prefix_mirror_lru_by_token_count(lm_setup):
    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        eng = EngineClient(server, rows=4, prompt_cap=16, prefix_tokens=8)
        p1, p2, p3 = [1, 2, 3], [4, 5, 6], [7, 8, 9]
        hits, misses, store, evict = eng._prefix_plan([p1, p2])
        assert not hits and store == [prefix_key(p1), prefix_key(p2)]
        # a repeat is a hit AND refreshes p1's LRU position
        hits, _, _, _ = eng._prefix_plan([p1])
        assert hits == [(0, prefix_key(p1))]
        # p3 (3 tokens) overflows the 8-token budget: LRU (now p2) evicts
        _, _, store, evict = eng._prefix_plan([p3])
        assert evict == [prefix_key(p2)] and store == [prefix_key(p3)]
        hits, misses, _, _ = eng._prefix_plan([p2])
        assert not hits and misses == [0]        # p2 was evicted: miss again
        eng.close()
        server.close(prune=False)


def test_engine_prefix_plan_cancels_same_group_store_evict(lm_setup):
    """A key stored and LRU-evicted within ONE plan must cancel out
    client-side (store slot nulled, no evict emitted): the worker applies
    evicts before stores, so emitting both would leak the entry past the
    budget forever."""
    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        eng = EngineClient(server, rows=4, prompt_cap=32, prefix_tokens=32)
        a = list(range(1, 21))                   # 20 tokens
        b = list(range(30, 50))                  # 20 tokens
        hits, misses, store, evict = eng._prefix_plan([a, b])
        # a was stored then evicted to make room for b — both commands
        # must vanish, leaving only b's store
        assert store == [None, prefix_key(b)]
        assert evict == []
        eng.close()
        server.close(prune=False)


def test_engine_state_lost_detection():
    assert is_state_lost(KeyError("state handle 'x' not resident"))
    assert not is_state_lost(KeyError("other"))
    assert not is_state_lost(ValueError("state handle"))


def test_engine_lease_released_on_close(lm_setup):
    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        eng = EngineClient(server, rows=2, prompt_cap=8)
        fut, order = eng.submit_admit([(0, [3, 1, 4])])
        fut.result()
        assert eng.handle in state.stats()["handles"]
        eng.close()
        assert eng.handle not in state.stats()["handles"]
        server.close(prune=False)


# ------------------------------------- composition-invariance (the matrix) --
# The ISSUE 5 acceptance matrix: iteration-level admission — staggered
# arrivals, slot reuse, prefix-cache hits — produces bit-identical greedy
# tokens to a solo wave, for every family, inline and in real worker
# processes (where the arena lives behind the wire and never comes back).

@pytest.mark.parametrize("backend", ("inline", "processes"))
def test_iteration_level_admission_is_composition_invariant(lm_family,
                                                            backend):
    fam, cfg, params = lm_family
    with Session(backend, os_threads=1) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        base = make_ragged_requests(cfg)
        # duplicate two prompts so admission sees prefix-cache hits; the
        # duplicates arrive later (staggered by the concurrency gate), so
        # hits insert into a *running* decode batch
        reqs = base + [Request(prompt=list(base[0].prompt), max_new=6),
                       Request(prompt=list(base[2].prompt), max_new=3)]
        solo = solo_reference(server, reqs)
        comps = run_continuous(server, reqs, concurrency=3, max_batch=3,
                               slots=1, max_wait_ms=5,
                               iteration_level=True, quantum=4,
                               prompt_cap=16)
        assert [c.tokens for c in comps] == solo
        # iteration-level really ran, and the duplicates hit the prefix
        for c in comps:
            assert c.ttft_ms is not None and c.ttft_ms <= c.latency_ms
        server.close(prune=False)


def test_iteration_prefix_hits_skip_prefill_and_match(lm_setup):
    """Repeated identical prompts: later admissions are served from the
    worker-resident prefix cache (stats prove it) and still decode to the
    solo reference tokens."""
    cfg, params = lm_setup
    shared = [11, 7, 5, 3]
    reqs = [Request(prompt=list(shared), max_new=4) for _ in range(4)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        solo = solo_reference(server, reqs)

        async def go():
            async with ContinuousBatcher(server, max_batch=2, slots=1,
                                         max_wait_ms=5, quantum=4,
                                         prompt_cap=8) as b:
                comps = await asyncio.gather(*[b.submit(r) for r in reqs])
                return comps, b.stats

        comps, stats = asyncio.run(go())
        assert [c.tokens for c in comps] == solo
        assert stats.mode == "iteration"
        assert stats.prefix_hits >= 1            # repeats skipped prefill
        assert stats.prefix_misses >= 1
        server.close(prune=False)


def test_iteration_disabled_prefix_cache_still_invariant(lm_setup):
    cfg, params = lm_setup
    reqs = [Request(prompt=[2, 4, 6], max_new=3),
            Request(prompt=[2, 4, 6], max_new=3)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        solo = solo_reference(server, reqs)
        comps = run_continuous(server, reqs, concurrency=2, max_batch=2,
                               slots=1, iteration_level=True,
                               prefix_tokens=0, prompt_cap=8)
        assert [c.tokens for c in comps] == solo
        server.close(prune=False)


def test_iteration_long_prompt_falls_back_to_wave(lm_setup):
    """A prompt above prompt_cap cannot live in the arena — it must still
    be served (solo wave fallback), identically to its solo run."""
    cfg, params = lm_setup
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=[1, 2, 3], max_new=3),
            Request(prompt=list(rng.integers(1, cfg.vocab_size, 40)),
                    max_new=3)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        solo = solo_reference(server, reqs)

        async def go():
            async with ContinuousBatcher(server, max_batch=2, slots=1,
                                         prompt_cap=8,
                                         iteration_level=True) as b:
                comps = await asyncio.gather(*[b.submit(r) for r in reqs])
                return comps, b.stats

        comps, stats = asyncio.run(go())
        assert [c.tokens for c in comps] == solo
        assert stats.wave_fallbacks == 1
        server.close(prune=False)


def test_paged_retires_the_prompt_cap_fallback(lm_setup):
    """The same over-cap prompt served from a paged arena (ISSUE 7):
    chunked prefill admits it iteration-level — no solo-wave fallback —
    and the tokens stay bit-identical to the solo run.  (The full paged
    matrix lives in tests/test_paged.py.)"""
    cfg, params = lm_setup
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=[1, 2, 3], max_new=3),
            Request(prompt=list(rng.integers(1, cfg.vocab_size, 40)),
                    max_new=3)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        solo = solo_reference(server, reqs)

        async def go():
            async with ContinuousBatcher(server, max_batch=2, slots=1,
                                         prompt_cap=8, paged=True,
                                         block_size=4,
                                         prefill_budget=8) as b:
                comps = await asyncio.gather(*[b.submit(r) for r in reqs])
                return comps, b.stats

        comps, stats = asyncio.run(go())
        assert [c.tokens for c in comps] == solo
        assert stats.wave_fallbacks == 0
        assert stats.live_tokens_peak > 0        # served from the block pool
        server.close(prune=False)


# ----------------------------------------- fleet invariance (ISSUE 6) ----
# The routing layer must be invisible in the tokens: prefix-routed
# placement, prefill→decode row migration over real CONTROL frames, and a
# mid-serve scale-up all decode bit-identically to the solo wave, on real
# worker processes, for an attention family and an ssm family (the two
# arena layouts: windowed seq keys vs whole-row recurrent state).

FLEET_FAMILIES = ("dense", "ssm")


@pytest.fixture(scope="module", params=FLEET_FAMILIES, ids=FLEET_FAMILIES)
def fleet_family(request):
    from conftest import FAMILY_ARCHS
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke(FAMILY_ARCHS[request.param]).replace(
        param_dtype="float32", compute_dtype="float32")
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_fleet_serving_is_composition_invariant_on_processes(fleet_family):
    from repro.fleet import FleetRouter, run_fleet

    fam, cfg, params = fleet_family
    with Session("processes", os_threads=1) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        base = make_ragged_requests(cfg)
        reqs = base + [Request(prompt=list(base[0].prompt), max_new=6),
                       Request(prompt=list(base[2].prompt), max_new=3)]
        solo = solo_reference(server, reqs)

        # (a) prefix-routed unified fleet: the duplicates pin to the
        # member whose worker-resident prefix store already holds them
        comps, s = run_fleet(server, reqs, n_members=2, policy="prefix",
                             max_batch=3, quantum=4, prompt_cap=16,
                             return_stats=True)
        assert [c.tokens for c in comps] == solo
        assert s["routing"]["prefix"] >= 1

        # (b) disaggregated: prefilled rows cross process boundaries
        # through cache_extract_rows/cache_insert_rows CONTROL frames
        comps, s = run_fleet(server, reqs, n_members=2, policy="p2c",
                             disaggregate=True, prefill_members=1,
                             max_batch=3, quantum=4, prompt_cap=16,
                             return_stats=True)
        assert [c.tokens for c in comps] == solo
        assert s["handoffs"] >= 1 and s["batcher"]["migrated_rows"] >= 1

        # (c) mid-serve scale-up: a member (and its worker) appears while
        # requests are in flight; placement changes, tokens must not
        async def go():
            async with FleetRouter(server, n_members=1, policy="p2c",
                                   max_batch=2, quantum=4,
                                   prompt_cap=16) as fleet:
                first = [asyncio.ensure_future(fleet.submit(r))
                         for r in reqs[:3]]
                await asyncio.sleep(0.05)    # decode under way on member 0
                fleet.grow(reason="mid-serve scale-up")
                rest = [asyncio.ensure_future(fleet.submit(r))
                        for r in reqs[3:]]
                comps = await asyncio.gather(*first, *rest)
                return comps, fleet.summary()

        comps, s = asyncio.run(go())
        assert [c.tokens for c in comps] == solo
        assert [e["action"] for e in s["scale_events"]] == ["grow"]
        assert s["n_members"] == 2
        server.close(prune=False)


def test_iteration_arena_compaction_under_sustained_load(lm_setup):
    """More sequential decode steps than the arena capacity: compaction
    must rebase live rows transparently (tokens stay solo-identical)."""
    cfg, params = lm_setup
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 4)),
                    max_new=8) for _ in range(8)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        solo = solo_reference(server, reqs)
        # cap 32, cursor0 8: eight staggered 8-token decodes push the
        # cursor far past 32 — only compaction keeps the arena serving
        comps = run_continuous(server, reqs, concurrency=2, max_batch=2,
                               slots=1, iteration_level=True, quantum=2,
                               prompt_cap=8, arena_cap=32)
        assert [c.tokens for c in comps] == solo
        server.close(prune=False)
