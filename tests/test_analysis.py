"""Deploy-time shippability analyzer (ISSUE 9).

Every rule gets a positive fixture the analyzer flags AND a runtime
demonstration that the flagged code really fails (or silently diverges)
on the ``processes`` backend un-analyzed — plus a near-miss fixture the
analyzer must NOT flag.  Also covered: the strict/warn deploy paths, the
runtime "likely cause" hint on a real worker NameError, the satellite
``freeze_function`` callable-capture fix, and the CLI's JSON schema.
"""
import asyncio
import dataclasses
import json
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.analysis import (AnalysisError, Diagnostic, RULES, SEVERITIES,
                            ShippabilityWarning, analyze_code,
                            analyze_function, match_diagnostics)
from repro.analysis.cli import main as cli_main
from repro.cloud import Session
from repro.core import freeze_function
from repro.core.codeship import _importable
from repro.core.function import data_captures, is_code_capture
from repro.serialization import register_custom


def main_like(src: str, filename: str = "/tmp/analysis_fixture.py") -> dict:
    """Build functions under a ``__main__``-like module, the fresh-globals
    shipping contract the RF101 rule is about."""
    g = {"__name__": "__main__"}
    exec(compile(textwrap.dedent(src), filename, "exec"), g)
    return g


def codes(diags):
    return {d.code for d in diags}


# -- module-level helpers: importable, ship by ref (near-miss territory) ----

MODULE_FACTOR = 7


def importable_uses_global(n):
    return MODULE_FACTOR * n


def rand_task(_):
    import random
    return random.random()


def global_writer(n):
    global ANALYSIS_SEEN
    ANALYSIS_SEEN = n          # worker-side module copy only
    return n


def make_counter_fn():
    counter = 0

    def bump(n):
        nonlocal counter
        counter += 1
        return counter
    return bump


def make_list_appender(xs):
    def appender(n):
        xs.append(n)
        return len(xs)
    return appender


@dataclasses.dataclass
class ShippableGain:
    """Serializable callable instance (registered): the RF104 case."""
    gain: float

    def __call__(self, x):
        return self.gain * x


register_custom(ShippableGain)


def make_gain_fn(g: ShippableGain):
    def apply(x):
        return g(x)
    return apply


@pytest.fixture(scope="module")
def proc():
    with Session("processes", os_threads=1) as s:
        yield s


# ---------------------------------------------------------------- rule table

def test_rule_table_is_stable():
    assert {"RF101", "RF102", "RF103", "RF104", "RF201", "RF202", "RF203",
            "RF301", "RF401", "RF402"} == set(RULES)
    for code, (sev, title) in RULES.items():
        assert sev in SEVERITIES and title


def test_diagnostic_json_roundtrip():
    d = Diagnostic(code="RF101", severity="error", message="m", symbol="X",
                   function="f", file="a.py", line=3)
    assert Diagnostic.from_json(d.to_json()) == d
    assert "a.py:3: RF101 error" in d.format()


# ------------------------------------------------- RF101: fresh globals ----

def test_rf101_flags_main_global_with_symbol_and_line():
    g = main_like("""
        FACTOR = 3
        def f(n):
            return FACTOR * n
    """)
    hits = [d for d in analyze_function(g["f"]) if d.code == "RF101"]
    assert hits and hits[0].symbol == "FACTOR"
    assert hits[0].severity == "error" and hits[0].line == 4


def test_rf101_near_miss_importable_module_function():
    assert _importable(importable_uses_global)
    assert "RF101" not in codes(analyze_function(importable_uses_global))


def test_rf101_near_miss_import_inside_body():
    g = main_like("""
        def f(n):
            import math
            return math.sqrt(n)
    """)
    assert "RF101" not in codes(analyze_function(g["f"]))


def test_rf101_demo_worker_name_error_with_hint(proc):
    g = main_like("""
        FACTOR = 3
        def f(n):
            return FACTOR * n
    """)
    fn = g["f"]
    assert fn(2) == 6                       # single-source: local call works
    with pytest.warns(ShippabilityWarning):
        fut = proc.function(fn, jax_traceable=False).submit(2)
    with pytest.raises(NameError, match="FACTOR") as ei:
        fut.result(timeout=60)
    # the transport error path appended the deploy-time diagnostic
    hint = getattr(ei.value, "analysis_hint", "")
    assert "RF101" in hint and "FACTOR" in hint
    assert "[repro.analysis]" in ei.value.remote_traceback


def test_rf101_downgraded_to_info_in_process():
    g = main_like("""
        FACTOR = 3
        def f(n):
            return FACTOR * n
    """)
    diags = analyze_function(g["f"], cross_process=False)
    assert all(d.severity == "info" for d in diags if d.code == "RF101")


# --------------------------------------------- RF102: host-only captures ----

def test_rf102_flags_lock_capture_and_submit_fails(proc):
    lock = threading.Lock()

    def guarded(n):
        with lock:
            return n
    hits = [d for d in analyze_function(guarded) if d.code == "RF102"]
    assert hits and hits[0].symbol == "lock"
    # runtime demo: the capture cannot even leave the client
    with pytest.warns(ShippabilityWarning):
        with pytest.raises(TypeError):
            proc.function(guarded, jax_traceable=False).submit(1)


def test_rf102_near_miss_array_capture():
    arr = np.arange(4.0)

    def scaled(n):
        return arr * n
    assert codes(analyze_function(scaled)) == set()


# -------------------------------------------- RF103: serialization probe ----

def test_rf103_flags_unregistered_instance():
    class Opaque:
        pass
    box = Opaque()

    def f(n):
        return (box, n)
    hits = [d for d in analyze_function(f) if d.code == "RF103"]
    assert hits and hits[0].symbol == "box"


def test_rf103_near_miss_registered_dataclass():
    g = ShippableGain(2.0)

    def f(x):
        return g.gain + x
    assert "RF103" not in codes(analyze_function(f))


# ------------------------------- RF104: callable capture without __code__ ----

def test_rf104_flags_callable_instance_and_it_ships_by_value(proc):
    g = ShippableGain(3.0)
    fn = make_gain_fn(g)
    hits = [d for d in analyze_function(fn) if d.code == "RF104"]
    assert hits and hits[0].severity == "info" and hits[0].symbol == "g"
    # satellite fix: freeze no longer explodes — payload slot, not code
    frozen = freeze_function(fn)
    assert frozen["freevars"]["g"] is None
    assert "g" in data_captures(fn) and not is_code_capture(g)
    # runtime demo: the value rides the payload and the call works
    assert proc.function(fn, jax_traceable=False).submit(2.0) \
        .result(timeout=60) == 6.0


def test_rf104_near_miss_plain_function_capture():
    def helper(x):
        return x + 1

    def f(n):
        return helper(n)
    assert "RF104" not in codes(analyze_function(f))


# ------------------------------------------------- RF201: capture writes ----

def test_rf201_flags_nonlocal_write():
    fn = make_counter_fn()
    hits = [d for d in analyze_function(fn) if d.code == "RF201"]
    assert hits and hits[0].symbol == "counter"
    assert hits[0].severity == "warning"


def test_rf201_near_miss_own_nested_closure_state():
    def f(n):
        state = 0

        def bump():
            nonlocal state
            state += 1
        bump()
        return state + n
    assert "RF201" not in codes(analyze_function(f))


def test_rf201_demo_lost_write_on_processes(proc):
    local = make_counter_fn()
    assert [local(0), local(0)] == [1, 2]   # local calls accumulate
    remote = make_counter_fn()
    h = proc.function(remote, jax_traceable=False)
    with pytest.warns(ShippabilityWarning):
        r1 = h.submit(0).result(timeout=60)
    r2 = h.submit(0).result(timeout=60)
    assert [r1, r2] == [1, 1]               # by-value capture: write is lost


# -------------------------------------------------- RF202: global writes ----

def test_rf202_flags_global_write():
    hits = [d for d in analyze_function(global_writer) if d.code == "RF202"]
    assert hits and hits[0].symbol == "ANALYSIS_SEEN"


def test_rf202_near_miss_global_read():
    assert "RF202" not in codes(analyze_function(importable_uses_global))


def test_rf202_demo_worker_module_state_never_lands_here(proc):
    with pytest.warns(ShippabilityWarning):
        out = proc.function(global_writer, jax_traceable=False) \
            .submit(41).result(timeout=60)
    assert out == 41
    assert "ANALYSIS_SEEN" not in globals()  # wrote the worker's copy only


# ---------------------------------------------- RF203: capture mutation ----

def test_rf203_flags_append_on_capture():
    fn = make_list_appender([])
    hits = [d for d in analyze_function(fn) if d.code == "RF203"]
    assert hits and "xs.append" in hits[0].symbol


def test_rf203_near_miss_local_list():
    def f(n):
        acc = []
        acc.append(n)
        return acc
    assert "RF203" not in codes(analyze_function(f))


def test_rf203_demo_mutation_stays_on_worker(proc):
    xs: list = []
    fn = make_list_appender(xs)
    with pytest.warns(ShippabilityWarning):
        out = proc.function(fn, jax_traceable=False).submit(5) \
            .result(timeout=60)
    assert out == 1
    assert xs == []                          # the client's list is untouched


# ------------------------------------------------ RF301: nondeterminism ----

def test_rf301_flags_random_time_uuid():
    g = main_like("""
        import random, time, uuid
        def f(n):
            return random.random() + time.time(), uuid.uuid4(), n
    """)
    got = codes([d for d in analyze_function(g["f"]) if d.code == "RF301"])
    assert got == {"RF301"}
    syms = {d.symbol for d in analyze_function(g["f"]) if d.code == "RF301"}
    assert {"random", "time.time", "uuid"} <= syms


def test_rf301_near_miss_seeded_and_monotonic():
    def f(n):
        import time
        import numpy as _np
        rng = _np.random.default_rng(7)
        return rng.normal() + time.monotonic() + n
    assert "RF301" not in codes(analyze_function(f))


def test_rf301_near_miss_shadowed_name():
    g = main_like("""
        random = 42                     # not the module
        def f(n):
            return random + n
    """)
    assert "RF301" not in codes(analyze_function(g["f"]))


def test_rf301_demo_bit_identity_broken(proc):
    assert "RF301" in codes(analyze_function(rand_task))
    h = proc.function(rand_task, jax_traceable=False)
    with pytest.warns(ShippabilityWarning):
        a = h.submit(0).result(timeout=60)
    b = h.submit(0).result(timeout=60)
    assert a != b                            # same payload, different result


# ------------------------------------------- RF401: coroutine entry point ----

async def async_entry(n):
    return n + 1


def test_rf401_flags_coroutine_entry():
    hits = [d for d in analyze_function(async_entry) if d.code == "RF401"]
    assert hits and hits[0].severity == "error"


def test_rf401_near_miss_sync_function():
    assert "RF401" not in codes(analyze_function(importable_uses_global))


def test_rf401_demo_coroutine_result_cannot_ship(proc):
    with pytest.warns(ShippabilityWarning):
        fut = proc.function(async_entry, jax_traceable=False).submit(1)
    with pytest.raises(Exception):
        fut.result(timeout=60)               # coroutine object: no wire form


# ------------------------------------- RF402: blocking inside coroutines ----

async def blocking_coro(n):
    import time
    time.sleep(0.2)
    return n


async def yielding_coro(n):
    await asyncio.sleep(0.2)
    return n


def test_rf402_flags_time_sleep_in_coroutine():
    hits = [d for d in analyze_function(blocking_coro)
            if d.code == "RF402"]
    assert hits and hits[0].symbol == "time.sleep"


def test_rf402_near_miss_asyncio_sleep():
    assert "RF402" not in codes(analyze_function(yielding_coro))


def test_rf402_near_miss_sleep_outside_coroutine():
    def f(n):
        import time
        time.sleep(0.0)
        return n
    assert "RF402" not in codes(analyze_function(f))


def test_async_session_bind_time_rf4_warning():
    # the serving layer surfaces RF4xx at bind time, before first submit
    import warnings as w

    from repro.serving import AsyncSession
    asess = AsyncSession("inline")
    try:
        with pytest.warns(ShippabilityWarning, match="RF402"):
            asess.function(blocking_coro, jax_traceable=False)
        with w.catch_warnings():
            w.simplefilter("error", ShippabilityWarning)
            asess.function(importable_uses_global, jax_traceable=False)
    finally:
        asess.close()


def test_rf402_demo_event_loop_stall():
    async def race(coro_fn):
        t0 = time.perf_counter()
        await asyncio.gather(coro_fn(0), coro_fn(1))
        return time.perf_counter() - t0
    blocked = asyncio.run(race(blocking_coro))
    overlapped = asyncio.run(race(yielding_coro))
    assert blocked >= 0.38                   # serialized: the loop stalled
    assert overlapped < blocked              # await overlaps the waits


# ------------------------------------------------- strict / warn deploys ----

def test_strict_session_rejects_at_deploy_before_anything_ships():
    g = main_like("""
        FACTOR = 3
        def f(n):
            return FACTOR * n
    """)
    with Session("processes", os_threads=1, strict_analysis=True) as s:
        with pytest.raises(AnalysisError) as ei:
            s.function(g["f"], jax_traceable=False).submit(2)
    msg = str(ei.value)
    assert "RF101" in msg and "FACTOR" in msg and ":4:" in msg
    assert all(d.code == "RF101" for d in ei.value.diagnostics)


def test_function_config_strict_opt_in():
    g = main_like("""
        FACTOR = 3
        def f(n):
            return FACTOR * n
    """)
    with Session("processes", os_threads=1) as s:
        with pytest.raises(AnalysisError):
            s.function(g["f"], jax_traceable=False, strict=True).submit(2)


def test_strict_in_process_backend_does_not_reject_rf101():
    # threads executes the client's own function object: fresh-globals
    # never bites, RF101 reports as info, strict mode lets it through
    g = main_like("""
        FACTOR = 3
        def f(n):
            return FACTOR * n
    """)
    with Session("threads", os_threads=2, strict_analysis=True) as s:
        assert s.function(g["f"], jax_traceable=False).submit(2) \
            .result(timeout=60) == 6


def test_clean_function_deploys_without_warning(proc):
    import warnings as w

    def clean(n):
        import math
        return math.sqrt(n)
    with w.catch_warnings():
        w.simplefilter("error", ShippabilityWarning)
        assert proc.function(clean, jax_traceable=False).submit(9.0) \
            .result(timeout=60) == 3.0


# ------------------------------------------------------- hint matching ----

def test_match_diagnostics_picks_named_symbol():
    d1 = Diagnostic(code="RF101", severity="error", message="m", symbol="A")
    d2 = Diagnostic(code="RF101", severity="error", message="m", symbol="B")
    err = NameError("name 'B' is not defined")
    assert match_diagnostics(err, [d1, d2]) == [d2]
    assert match_diagnostics(ValueError("unrelated"), [d1, d2]) == []


# ------------------------------------------------------------------ CLI ----

CLI_BAD = """\
import repro.cloud as cloud

HELPER = 2


def task(n):
    return HELPER * n


with cloud.Session("threads") as sess:
    sess.function(task).submit(1)
    sess.function(lambda x: HELPER + x).submit(2)
"""

CLI_CLEAN = """\
import repro.cloud as cloud


def task(n):
    import math
    return math.sqrt(n)


with cloud.Session("threads") as sess:
    sess.function(task).submit(1)
"""


def test_cli_json_schema_and_exit_code(tmp_path, capsys):
    p = tmp_path / "bad_script.py"
    p.write_text(CLI_BAD)
    rc = cli_main([str(p), "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["version"] == 1 and out["files"] == 1
    assert out["functions"] == 2             # the def and the lambda
    assert out["errors"] >= 2
    d = out["diagnostics"][0]
    assert {"code", "severity", "message", "symbol", "function", "file",
            "line"} <= set(d)
    assert any(x["code"] == "RF101" and x["symbol"] == "HELPER"
               for x in out["diagnostics"])


def test_cli_clean_script_exits_zero(tmp_path, capsys):
    p = tmp_path / "ok_script.py"
    p.write_text(CLI_CLEAN)
    assert cli_main([str(p)]) == 0
    assert "0 error(s)" in capsys.readouterr().out


def test_cli_strict_fails_on_warnings(tmp_path, capsys):
    p = tmp_path / "warny.py"
    p.write_text(textwrap.dedent("""\
        def task(n):
            import random
            return random.random() + n

        sess.function(task)
    """))
    assert cli_main([str(p)]) == 0           # warning-only: default passes
    capsys.readouterr()
    assert cli_main([str(p), "--strict"]) == 1


def test_cli_package_module_not_rf101_flagged(tmp_path, capsys, monkeypatch):
    # regression for the namespace/package module-name derivation: a
    # function in an importable package keeps its module globals
    pkg = tmp_path / "clipkg"
    pkg.mkdir()
    (pkg / "__init__.py").write_text("")
    (pkg / "mod.py").write_text(textwrap.dedent("""\
        SCALE = 2


        def task(n):
            return SCALE * n


        def run(sess):
            return sess.function(task).submit(1)
    """))
    monkeypatch.syspath_prepend(str(tmp_path))
    assert cli_main([str(pkg / "mod.py")]) == 0
    capsys.readouterr()


def test_cli_self_lint_apps_and_examples_clean(capsys):
    # satellite: the shipped apps/examples must stay lint-clean (false
    # positives found while running this are fixed in the analyzer, not
    # silenced here)
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    rc = cli_main([str(root / "src" / "repro" / "apps"),
                   str(root / "examples")])
    out = capsys.readouterr().out
    assert rc == 0, out


def test_cli_missing_target_exits_two(capsys):
    assert cli_main(["definitely_not_a_module_xyz"]) == 2
