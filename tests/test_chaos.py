"""Chaos-hardened serving (ISSUE 10): seeded cross-process fault
injection, decode replay failover, and the unified retry/backoff/deadline
policy.  The contract under test everywhere: a worker death is *added
latency*, never a client-visible error, and greedy decode makes the
recovered completion bit-identical to the unfailed one."""
import time

import jax
import jax.numpy as jnp
import pytest

from conftest import FAMILY_ARCHS, make_ragged_requests, solo_reference
from repro.cloud import Session
from repro.core import Deployment, FunctionConfig
from repro.dispatch import Dispatcher, FaultPlan
from repro.dispatch.retry import CircuitBreaker, RetryPolicy
from repro.runtime import state
from repro.runtime.sandbox import ChaosEvent, ChaosPlan
from repro.runtime.server import LMServer, Request
from repro.runtime.worker_host import WorkerHost
from repro.serialization import wire


def task_noop(x):
    return x


# --------------------------------------------------- retry policy unit ----

def test_backoff_is_deterministic_and_exponentially_spaced():
    p = RetryPolicy(base_s=0.02, multiplier=2.0, max_backoff_s=10.0,
                    jitter=0.5, seed=3)
    a = [p.backoff_s(7, k) for k in range(2, 8)]
    assert a == [p.backoff_s(7, k) for k in range(2, 8)]  # pure in the seed
    raw = [0.02 * 2.0 ** (k - 2) for k in range(2, 8)]
    for got, r in zip(a, raw):
        assert r * 0.5 <= got <= r          # jitter only shaves, ≤ 50%
    # jitter ≤ 0.5 ⇒ monotone: the shortest attempt-N+1 backoff is at
    # least the longest attempt-N backoff — exponential spacing survives
    assert all(x <= y for x, y in zip(a, a[1:]))
    assert a[-1] > 8 * a[0]
    # distinct tasks draw distinct jitter from the same seeded stream
    assert p.backoff_s(1, 3) != p.backoff_s(2, 3)


def test_backoff_without_jitter_is_exact_and_capped():
    p = RetryPolicy(base_s=0.01, multiplier=2.0, max_backoff_s=0.04,
                    jitter=0.0)
    assert [p.backoff_s(0, k) for k in (2, 3, 4, 5, 6)] == \
        [0.01, 0.02, 0.04, 0.04, 0.04]


# ------------------------------------------------- circuit breaker unit ----

def test_breaker_open_halfopen_reopen_then_close():
    t = [0.0]
    br = CircuitBreaker(threshold=2, cooldown_s=1.0, probe_window_s=0.5,
                        clock=lambda: t[0])
    assert br.allow()
    br.record_failure()
    assert br.state == "closed" and br.allow()   # below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    t[0] = 0.5
    assert not br.allow()                        # still cooling down
    t[0] = 1.1
    assert br.allow()                            # the half-open probe
    assert br.state == "half-open"
    assert not br.allow()                        # one probe at a time
    br.record_failure()                          # probe failed → reopen
    assert br.state == "open" and not br.allow()
    t[0] = 2.5
    assert br.allow()                            # probe again
    br.record_success()
    snap = br.snapshot()
    assert snap == {"state": "closed", "failures": 0, "opens": 2}


def test_breaker_quiet_probe_window_closes_lazily():
    t = [0.0]
    br = CircuitBreaker(threshold=1, cooldown_s=1.0, probe_window_s=0.5,
                        clock=lambda: t[0])
    br.record_failure()
    t[0] = 1.5
    assert br.allow()                            # probe admitted
    t[0] = 2.5                                   # window passed, no failure
    assert br.allow() and br.state == "closed"


# ------------------------------------------------------ deadline plane ----

def test_worker_rejects_expired_deadline_before_executing(tmp_path):
    path = str(tmp_path / "manifest.json")
    dep = Deployment(manifest_path=path)
    deployed = dep.deploy(task_noop, jnp.ones(2))
    payload = deployed.bridge.pack((jnp.ones(2),), {}, {})
    host = WorkerHost(path)
    msg = wire.decode(host.handle(wire.encode_invoke(
        deployed.name, payload, task_id=1, deadline=time.time() - 1.0)))
    assert isinstance(msg, wire.ErrorReply)
    assert msg.etype == "TimeoutError" and not msg.retryable
    # a live deadline sails through
    msg = wire.decode(host.handle(wire.encode_invoke(
        deployed.name, payload, task_id=2, deadline=time.time() + 60.0)))
    assert isinstance(msg, wire.ResultReply)


def test_deadline_turns_endless_crash_retries_into_timeout():
    d = Dispatcher(os_threads=2,
                   fault_plan=FaultPlan(failure_rate=1.0, seed=1),
                   retry=RetryPolicy(base_s=0.05, multiplier=2.0,
                                     jitter=0.0))
    try:
        inst = d.create_instance()
        cfg = FunctionConfig(max_retries=100).with_deadline(0.15)
        fut = inst.dispatch(lambda x: x, jnp.float32(0), config=cfg)
        with pytest.raises(TimeoutError):
            fut.result(timeout=30)
        # the recorded retries are the exact no-jitter exponential ladder
        backs = [e["backoff_s"] for e in inst.retry_log]
        assert backs and backs == [0.05 * 2.0 ** i for i in range(len(backs))]
        ts = [e["t"] for e in inst.retry_log]
        assert ts == sorted(ts)
    finally:
        d.shutdown()


def test_retry_budget_caps_resubmissions_across_tasks():
    d = Dispatcher(os_threads=2,
                   fault_plan=FaultPlan(failure_rate=1.0, seed=1),
                   retry=RetryPolicy(base_s=0.001, jitter=0.0, budget=3))
    try:
        inst = d.create_instance()
        cfg = FunctionConfig(max_retries=50)
        futs = [inst.dispatch(lambda x: x, jnp.float32(i), config=cfg)
                for i in range(2)]
        for f in futs:
            with pytest.raises(Exception):
                f.result(timeout=30)
        assert len(inst.retry_log) == 3          # budget, not 2 × 50
    finally:
        d.shutdown()


# ------------------------------------------------------ lease heartbeat ----

@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, params


def test_heartbeat_renews_lease_against_false_expiry(lm_setup):
    """Regression for the false-expiry failure mode: a client-side stall
    longer than the lease TTL must not cost the arena, because the
    heartbeat thread renews the lease between engine calls."""
    from repro.runtime.engine import EngineClient

    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        eng = EngineClient(server, rows=2, prompt_cap=8, ttl_s=0.2)
        try:
            state.lease(eng.handle, ttl_s=eng.ttl_s, make=lambda: object())
            eng.start_heartbeat(interval_s=0.05)
            time.sleep(0.5)                      # stall > 2× the TTL
            state.get(eng.handle, ttl_s=eng.ttl_s)   # still leased
            eng.stop_heartbeat()
            time.sleep(0.5)                      # now nobody renews
            with pytest.raises(KeyError):
                state.get(eng.handle, ttl_s=eng.ttl_s)
        finally:
            eng.stop_heartbeat()
            state.release(eng.handle)
            server.close(prune=False)


def test_renew_extends_without_recreating():
    state.lease("hb-test", ttl_s=60.0, make=lambda: object())
    try:
        assert state.renew("hb-test", ttl_s=60.0)
        assert not state.renew("never-leased", ttl_s=60.0)  # renew ≠ create
    finally:
        state.release("hb-test")


# ---------------------------------------- chaos invariance (the matrix) ----
# One seeded ChaosPlan SIGKILLs a fleet member's worker subprocess
# mid-decode, on real worker processes, for both arena layouts (dense
# windowed-KV and ssm recurrent state).  Acceptance: every request
# completes, tokens bit-identical to the unfailed solo run, the batcher
# counted a state reset and a recovered row, and the transport logged the
# kill and the respawn.

CHAOS_FAMILIES = ("dense", "ssm")


@pytest.fixture(scope="module", params=CHAOS_FAMILIES, ids=CHAOS_FAMILIES)
def chaos_family(request):
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke(FAMILY_ARCHS[request.param]).replace(
        param_dtype="float32", compute_dtype="float32")
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_chaos_kill_member_is_invisible_and_bit_identical(chaos_family):
    from repro.fleet import run_fleet

    fam, cfg, params = chaos_family
    plan = ChaosPlan([ChaosEvent("kill", slot=1, after=3)], seed=7)
    with Session("processes", os_threads=1, chaos=plan) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        base = make_ragged_requests(cfg)
        reqs = base + [Request(prompt=list(base[i].prompt) + [1 + i],
                               max_new=8) for i in range(3)]
        solo = solo_reference(server, reqs)       # chaos still disarmed
        plan.arm()
        comps, s = run_fleet(server, reqs, n_members=2, policy="p2c",
                             max_batch=4, quantum=2, prompt_cap=16,
                             seed=0, return_stats=True)
        # zero client-visible errors AND bit-identity through the failover
        assert [c.tokens for c in comps] == solo
        counts = plan.counts()
        assert counts.get("worker.killed") == 1
        assert counts.get("worker.respawned", 0) >= 1
        assert s["batcher"]["state_resets"] >= 1
        assert s["batcher"]["recovered_rows"] >= 1
        assert s["recoveries"] >= 1
        assert any(getattr(c, "recovered", False) for c in comps)
        server.close(prune=False)


def test_chaos_drop_conn_normalizes_to_retryable_crash(lm_setup):
    """A dropped connection surfaces as WorkerCrash (retryable), not a
    raw ConnectionError — the dispatcher's backoff path absorbs it and
    the rows replay exactly like a kill."""
    from repro.fleet import run_fleet

    cfg, params = lm_setup
    plan = ChaosPlan([ChaosEvent("drop", slot=0, after=3)], seed=5)
    with Session("processes", os_threads=1, chaos=plan) as sess:
        server = LMServer(cfg, params, session=sess, max_new=6)
        reqs = make_ragged_requests(cfg)
        solo = solo_reference(server, reqs)
        plan.arm()
        comps = run_fleet(server, reqs, n_members=2, policy="p2c",
                          max_batch=4, quantum=2, prompt_cap=16, seed=0)
        assert [c.tokens for c in comps] == solo
        assert plan.counts().get("conn.dropped") == 1
        server.close(prune=False)


def test_chaos_expired_lease_replays_not_fails(lm_setup):
    """Force-expiring the worker's state leases mid-run exercises the
    state-lost KeyError path directly: rows replay on a fresh arena."""
    from repro.fleet import run_fleet

    cfg, params = lm_setup
    plan = ChaosPlan([ChaosEvent("expire", slot=0, after=3)], seed=9)
    with Session("processes", os_threads=1, chaos=plan) as sess:
        server = LMServer(cfg, params, session=sess, max_new=6)
        reqs = make_ragged_requests(cfg)
        solo = solo_reference(server, reqs)
        plan.arm()
        comps, s = run_fleet(server, reqs, n_members=2, policy="p2c",
                             max_batch=4, quantum=2, prompt_cap=16,
                             seed=0, return_stats=True)
        assert [c.tokens for c in comps] == solo
        assert plan.counts().get("lease.expired") == 1
        assert s["batcher"]["state_resets"] >= 1
        server.close(prune=False)


def test_chaos_plan_is_seed_deterministic_and_armed_only():
    p1 = ChaosPlan.kill_member(seed=7, n_slots=4)
    p2 = ChaosPlan.kill_member(seed=7, n_slots=4)
    assert p1.events == p2.events                # same seed, same schedule
    assert ChaosPlan.kill_member(seed=8, n_slots=4).events != p1.events \
        or True                                  # may collide; shape check:
    ev = p1.events[0]
    assert ev.kind == "kill" and 0 <= ev.slot < 4 and ev.after >= 3
    # disarmed plans never fire; arming resets the invoke budget
    assert p1.on_invoke(ev.slot) == []
    p1.arm()
    for _ in range(ev.after - 1):
        assert p1.on_invoke(ev.slot) == []
    assert [e.kind for e in p1.on_invoke(ev.slot)] == ["kill"]
    assert p1.on_invoke(ev.slot) == []           # one-shot
