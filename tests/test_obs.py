"""Observability plane (ISSUE 8): trace context on the wire, span
stitching across the process boundary, metrics registry math, exporters,
and the tracing-off overhead guard."""
import json

import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serialization import wire


@pytest.fixture(autouse=True)
def _clean_tracer():
    """The process tracer is shared state — every test starts and ends
    hard-off with an empty ring."""
    t = obs_trace.TRACER
    t.configure(enabled=False, sample=0.0)
    t.reset()
    yield
    t.configure(enabled=False, sample=0.0)
    t.reset()


# Module-level tasks: shippable to worker processes by reference.
def task_double(x):
    return x * 2


def task_exit(x):
    import os
    os._exit(13)               # sandbox loss: no goodbye on the wire


# ------------------------------------------------------------------ wire ----

def test_wire_trace_roundtrip():
    ctx = {"tid": "t1", "sid": "s1", "t0": 12.5}
    frame = wire.encode_invoke("fn", b"p", task_id=1, attempt=1, trace=ctx)
    msg = wire.decode(frame)
    assert msg.trace == ctx

    spans = [{"name": "worker.entry", "tid": "t1", "sid": "w1",
              "parent": "s1", "t0": 12.5, "dur": 0.01, "proc": "worker"}]
    reply = wire.decode(wire.encode_result(b"b", stats={}, server_s=0.1,
                                           spans=spans))
    assert reply.spans == spans
    err = wire.decode(wire.encode_error(etype="ValueError", retryable=False,
                                        message="boom", spans=spans))
    assert err.spans == spans


def test_wire_trace_is_additive():
    """Untraced frames carry no trace/spans header fields at all (an old
    worker never sees the key), and decoding an old-style frame without
    them fills the defaults."""
    frame = wire.encode_invoke("fn", b"p", task_id=1, attempt=1)
    assert b'"trace"' not in frame
    assert wire.decode(frame).trace is None
    reply = wire.encode_result(b"b", stats={}, server_s=0.1)
    assert b'"spans"' not in reply
    assert wire.decode(reply).spans == []


# ------------------------------------------------------------- stitching ----

def test_span_stitching_across_processes():
    """One traced request through the real ``processes`` backend: the
    worker-side spans come back on the reply envelope and parent under the
    client's submit span — one tree spanning two pids."""
    from repro.cloud import Session
    obs_trace.configure(sample=1.0)
    with Session("processes", os_threads=1) as sess:
        f = sess.function(task_double, jax_traceable=False)
        assert f.submit(3).result() == 6
    spans = obs_trace.TRACER.spans()
    by_name = {s.name: s for s in spans}
    root = by_name["client.submit"]
    assert root.parent_id is None and root.proc == "client"
    assert {"client.transport", "worker.decode", "worker.entry"} \
        <= set(by_name)
    for name in ("worker.decode", "worker.compile", "worker.entry"):
        s = by_name[name]
        assert s.proc == "worker"
        assert s.trace_id == root.trace_id
        assert s.parent_id == root.span_id
        assert s.pid != root.pid          # genuinely crossed a process
    assert by_name["client.transport"].parent_id == root.span_id
    assert by_name["worker.entry"].attrs.get("cold_start") is True


def test_worker_error_context_on_failing_span():
    """A crashed worker's epitaph (exit detail) lands on the transport
    span, and the submit span records the failure type."""
    from repro.cloud import Session
    obs_trace.configure(sample=1.0)
    with Session("processes", os_threads=1) as sess:
        f = sess.function(task_exit, jax_traceable=False)
        with pytest.raises(Exception):
            f.submit(1).result()
    errs = [s for s in obs_trace.TRACER.spans() if s.status == "error"]
    assert errs, "a failing request must produce error-status spans"
    transport = [s for s in errs if s.name == "client.transport"]
    assert transport and "error.type" in transport[0].attrs
    assert "error.detail" in transport[0].attrs


# --------------------------------------------------------------- metrics ----

def test_histogram_bucket_math():
    h = obs_metrics.Histogram("h", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0, 5.0, 50.0, 500.0, 1000.0):
        h.observe(v)
    s = h.series()
    # le semantics: a value equal to a bound counts in that bound's bucket
    assert s["counts"] == [2, 1, 1, 2]
    assert s["count"] == 6
    assert s["sum"] == pytest.approx(1556.5)
    assert h.cumulative() == [2, 3, 4, 6]


def test_registry_merge_and_labels():
    a, b = obs_metrics.Registry(), obs_metrics.Registry()
    a.counter("c").inc(2, k="x")
    b.counter("c").inc(3, k="x")
    b.counter("c").inc(1, k="y")
    a.gauge("g").set(4)
    b.gauge("g").set(5)
    a.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    b.histogram("h", buckets=(1.0, 2.0)).observe(0.5)
    b.histogram("other", buckets=(9.0,)).observe(1.0)
    a.merge(b.snapshot())
    assert a.counter("c").value(k="x") == 5
    assert a.counter("c").value(k="y") == 1
    assert a.gauge("g").value() == 9       # summed: fleet total of a gauge
    assert a.histogram("h", buckets=(1.0, 2.0)).series()["counts"] \
        == [1, 1, 0]
    assert a.get("other") is not None      # unknown names are created


def test_prometheus_exposition():
    reg = obs_metrics.Registry()
    reg.counter("reqs", "requests handled").inc(3, backend="x")
    reg.histogram("lat_ms", buckets=(1.0, 10.0)).observe(0.5)
    text = reg.render()
    assert "# HELP reqs requests handled" in text
    assert "# TYPE reqs counter" in text
    assert 'reqs{backend="x"} 3' in text
    assert "# TYPE lat_ms histogram" in text
    assert 'lat_ms_bucket{le="1"} 1' in text
    assert 'lat_ms_bucket{le="+Inf"} 1' in text
    assert "lat_ms_sum 0.5" in text
    assert "lat_ms_count 1" in text


def test_session_stats_carries_metrics():
    from repro.cloud import Session
    with Session("threads", os_threads=2) as sess:
        f = sess.function(task_double, jax_traceable=False)
        assert f.submit(5).result() == 10
        m = sess.stats()["metrics"]
    assert m["sandbox_cold_starts_total"]["type"] == "counter"
    assert sum(m["sandbox_cold_starts_total"]["values"].values()) >= 1
    assert sum(m["entry_busy_seconds_total"]["values"].values()) > 0


# --------------------------------------------------------------- sampler ----

def test_sampler_seeded_determinism():
    a = obs_trace.Sampler(0.5, seed=7)
    b = obs_trace.Sampler(0.5, seed=7)
    seq = [a.decide() for _ in range(64)]
    assert seq == [b.decide() for _ in range(64)]
    assert any(seq) and not all(seq)
    assert all(obs_trace.Sampler(1.0, seed=1).decide() for _ in range(8))
    assert not any(obs_trace.Sampler(0.0, seed=1).decide()
                   for _ in range(8))


# -------------------------------------------------------------- exporter ----

def test_chrome_export_schema(tmp_path):
    obs_trace.configure(sample=1.0)
    root = obs_trace.TRACER.start_trace("client.submit", function="f")
    child = obs_trace.TRACER.span("client.transport", root.ctx, slot=0)
    child.finish()
    root.finish()
    path = tmp_path / "trace.json"
    n = obs_trace.dump_trace(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert n == len(events) == 2
    for ev in events:
        assert ev["ph"] == "X"
        assert {"name", "ts", "dur", "pid", "tid", "args"} <= set(ev)
    sub = next(e for e in events if e["name"] == "client.submit")
    tra = next(e for e in events if e["name"] == "client.transport")
    assert tra["args"]["parent_span_id"] == sub["args"]["span_id"]
    assert tra["args"]["trace_id"] == sub["args"]["trace_id"]
    assert sub["args"]["parent_span_id"] is None


# --------------------------------------------------------- overhead guard ----

def test_disabled_tracing_makes_no_instrumentation_calls():
    """The hard off-switch: with tracing off every site returns before
    counting as an engagement — ``calls`` stays 0 end to end."""
    from repro.cloud import Session
    t = obs_trace.TRACER
    assert not t.enabled and t.calls == 0
    assert t.start_trace("x") is obs_trace.NOOP
    assert t.span("x") is obs_trace.NOOP
    t.span_at("x", obs_trace.SpanContext("t", "s"), 0.0, 0.0)
    t.ingest([{"name": "x"}])
    assert t.calls == 0 and t.spans() == []

    with Session("threads", os_threads=2) as sess:
        f = sess.function(task_double, jax_traceable=False)
        assert f.submit(4).result() == 8
    assert t.calls == 0 and t.spans() == []
