"""MoE dispatch properties — the in-core mirror of the paper's dispatcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import mlp_apply
from repro.models.moe import moe_apply, moe_init

KEY = jax.random.PRNGKey(0)


def _setup(e=4, d=16, f=32, act="swiglu"):
    p, s = moe_init(KEY, d, f, e, act, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    return p, x


def test_identical_experts_equal_dense_mlp():
    """If all experts share weights, routed output == a plain MLP
    (gates sum to 1, no drops at high capacity) — the strongest end-to-end
    correctness property of the dispatch/combine path."""
    e, d, f = 4, 16, 32
    p, x = _setup(e, d, f)
    for nm in ("wi", "wg", "wo"):
        p[nm] = jnp.broadcast_to(p[nm][:1], p[nm].shape)
    y, m = jax.jit(lambda p, x: moe_apply(
        p, x, n_experts=e, top_k=2, capacity_factor=8.0,
        act="swiglu"))(p, x)
    dense = mlp_apply({"wi": p["wi"][0], "wg": p["wg"][0],
                       "wo": p["wo"][0]}, x, "swiglu")
    assert float(m["moe_drop"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_capacity_drops_accounted():
    e, d, f = 4, 16, 32
    p, x = _setup(e, d, f)
    # capacity_factor tiny -> guaranteed drops, reported in metrics
    y, m = jax.jit(lambda p, x: moe_apply(
        p, x, n_experts=e, top_k=2, capacity_factor=0.25,
        act="swiglu"))(p, x)
    assert float(m["moe_drop"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_grads_flow_to_all_parts():
    e, d, f = 4, 16, 32
    p, x = _setup(e, d, f)

    def loss(p, x):
        y, m = moe_apply(p, x, n_experts=e, top_k=2, capacity_factor=2.0,
                         act="swiglu")
        return jnp.sum(y ** 2) + 0.01 * m["moe_aux"]

    g = jax.grad(loss)(p, x)
    for k, v in g.items():
        assert bool(jnp.all(jnp.isfinite(v))), k
        assert float(jnp.sum(jnp.abs(v))) > 0.0, f"zero grad for {k}"


def test_aux_loss_balanced_router_is_minimal():
    """A perfectly uniform router gives aux == 1 (its minimum)."""
    e, d, f = 4, 16, 32
    p, x = _setup(e, d, f)
    p["router"] = jnp.zeros_like(p["router"])          # uniform probs
    _, m = jax.jit(lambda p, x: moe_apply(
        p, x, n_experts=e, top_k=2, capacity_factor=4.0,
        act="swiglu"))(p, x)
    assert abs(float(m["moe_aux"]) - 1.0) < 0.3
