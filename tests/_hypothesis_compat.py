"""Optional-dependency guard for ``hypothesis`` (tier-1 on minimal installs).

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real thing when hypothesis is installed.  Without it, property tests
are collected but *skipped* (not errored), and strategy expressions used at
module scope (``st.integers(...)``, ``a | b``, ``.map``/``.flatmap``)
evaluate harmlessly to inert placeholders — so plain pytest tests in the
same module keep running.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # minimal install: skip property tests, run the rest
    import pytest

    HAVE_HYPOTHESIS = False

    class _InertStrategy:
        """Absorbs any strategy-building expression at module scope."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

        def __ror__(self, other):
            return self

    st = _InertStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install "
                       "'repro-cppless[test]')")(fn)
        return deco

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
